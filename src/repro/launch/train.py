"""Training driver: end-to-end loop with checkpoint/restart + fault hooks.

The step resolves through the ``repro.comm`` "train_step" registry
(``build_train_step_lane``): ``--gradsync`` accepts every registered
strategy (derived from the registry, incl. ``auto``, the ZeRO flavors
and the quorum-degraded ``lane_quorum``), ``--gradsync-buckets`` /
``--fsdp-prefetch`` are the §5 tuning knobs, and the master
parameter/optimizer layout (replicated vs ZeRO-1 flat moments vs the
ZeRO-3 (L, B, p, s) layer masters) follows ``LaneComm.param_layout`` via
``launch.steps.init_lane_train_state`` — checkpoints canonicalize
through the matching layout so a ``lane_zero3`` checkpoint written at p
chips restores bit-identically at p′ chips.

Examples
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt runs/ckpt_demo \
      --gradsync lane_zero3 --pods 2
  (production: same entry point under one process per host with
   jax.distributed.initialize(); the mesh comes from launch/mesh.py)

Fault tolerance — the recovery ladder (HEALTHY → DEGRADED → RESTART):
  * ``--fault-plan`` injects a deterministic runtime.faults.FaultPlan
    (pod_slow / pod_lost / ckpt_io / corrupt_leaf) so every rung runs
    under tier-1 with no real hardware; ``seed:<n>`` draws a seeded
    random plan.
  * a runtime.watchdog.Watchdog folds per-pod progress heartbeats into
    the 0/1 contributing mask; under ``--gradsync lane_quorum`` the
    step takes that mask and DEGRADED steps proceed with the
    quorum-rescaled gradient (masked pods contribute zero; their
    (seed, step)-keyed microbatch rows are logged and replayable).
  * runtime.health.HealthMonitor bounds the staleness
    (``--quorum-staleness`` K): a pod masked for more than K
    consecutive steps — or ANY masked pod under a strategy with no
    quorum path — escalates to RESTART: emergency checkpoint, then
    ``plan_elastic_mesh`` re-plans around the lost pod's devices and
    the attempt loop resumes on the survivors (``--max-restarts``
    bounds it).  The in-process restart is bit-identical to killing
    the job and re-launching with ``--lose-chips``.
  * resume: picks up from the newest checkpoint that VERIFIES (per-leaf
    crc32; a corrupt latest falls back to the previous committed step);
    the data pipeline is (seed, step)-keyed so the token stream
    continues exactly
  * SIGTERM → emergency checkpoint before exit (preemption handling);
    the emergency save records the last COMPLETED step, never a step
    that raised or was interrupted mid-flight
  * elastic restart: ``--lose-chips`` re-plans the mesh around lost
    devices (runtime.elastic) and the layout-aware restore re-shards the
    canonical checkpoint onto the survivors
  * async checkpoint writer off the critical path with bounded
    retry-with-backoff for transient I/O errors; worker errors surface
    on the emergency path instead of dying with the daemon thread
"""
from __future__ import annotations

import argparse
import math
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import resolve, RunConfig
from repro.configs.base import ShapeConfig
from repro.models import init_model
from repro.optim import AdamWConfig
from repro.checkpoint import AsyncCheckpointer, latest_step
from repro.data import make_loader
from repro.launch.mesh import batch_axes
from repro.launch.steps import (build_train_step_lane, init_lane_train_state,
                                restore_lane_train_state)
from repro.runtime.elastic import plan_elastic_mesh
from repro.runtime.faults import FaultPlan, corrupt_leaf_file
from repro.runtime.health import DEGRADED, RESTART, HealthMonitor
from repro.runtime.watchdog import Watchdog


def make_mesh_auto(batch: int = 1 << 30, pods: int = 1, tp: int = 1):
    """Widest (data, model) factorization of the local devices that still
    divides ``batch``; ``pods > 1`` adds the cross-DCN "pod" axis (the
    lane level) as the outermost batch axis.  ``tp > 1`` pins the
    "model" axis to exactly that size (tensor parallelism): the mesh
    becomes the full 3D ``pods × data × model`` grid with data taking
    everything the pod and model axes leave."""
    n = len(jax.devices())
    pods = max(pods, 1)
    tp = max(tp, 1)
    if n % pods:
        raise ValueError(f"{n} devices not divisible into {pods} pods")
    if pods > 1 and batch % pods:
        # fail here with the real reason, not deep inside shard_map's
        # divisibility machinery
        raise ValueError(
            f"global batch {batch} not divisible by the {pods}-pod lane "
            f"axis; pick a batch divisible by --pods")
    per = n // pods
    if tp > 1:
        if per % tp:
            raise ValueError(
                f"{per} devices per pod not divisible by "
                f"--model-parallel {tp}")
        d = per // tp
        if batch % max(pods * d, 1):
            raise ValueError(
                f"global batch {batch} not divisible by the {pods}×{d} "
                f"batch grid that --model-parallel {tp} leaves on "
                f"{n} devices; pick a divisible batch (or change "
                f"--pods/--model-parallel)")
        if pods > 1:
            return jax.make_mesh((pods, d, tp), ("pod", "data", "model"))
        return jax.make_mesh((d, tp), ("data", "model"))
    d = 1
    while d * 2 <= per and per % (d * 2) == 0 \
            and batch % (pods * d * 2) == 0:
        d *= 2
    m = per // d
    if pods > 1:
        return jax.make_mesh((pods, d, m), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((d, m), ("data", "model"))


def _tree_alive(tree) -> bool:
    """False when any leaf buffer was deleted (donated into a step call
    that raised) — an emergency save would die on device_get."""
    return all(not (hasattr(l, "is_deleted") and l.is_deleted())
               for l in jax.tree.leaves(tree))


def _resolve_pods(pods: int, gradsync: str) -> int:
    """0 = auto: lane_zero3 needs distinct lane/node batch axes, so give
    it a pod axis whenever the device count allows; everything else
    defaults to the single-pod mesh."""
    if pods:
        return pods
    n = len(jax.devices())
    if gradsync == "lane_zero3" and n >= 4 and n % 2 == 0:
        return 2
    return 1


def _outer_axis(mesh0) -> int:
    """Index of the outermost batch axis (the lane/pod level) — the axis
    plan_elastic_mesh shrinks and the watchdog's quorum is over."""
    names = mesh0.axis_names
    for a in ("pod", "data"):
        if a in names:
            return names.index(a)
    raise ValueError(f"no batch axis in {names}")


def _restart_flat_indices(mesh0, lost, pod_ranks) -> list:
    """Map CURRENT-mesh lane ranks the health ladder condemned back to
    ORIGINAL-mesh flat device indices.

    The current mesh is the original minus the outer-axis slices that
    contain ``lost``; the surviving outer coordinates, in order, ARE the
    current lane ranks.  Returning original-mesh indices keeps one
    canonical bookkeeping: replanning from (mesh0, lost ∪ these) is
    byte-for-byte the ``--lose-chips`` path, so an in-process restart is
    bit-identical to a fresh launch that lost the same pods.
    """
    shape0 = mesh0.devices.shape
    outer = _outer_axis(mesh0)
    dropped = {np.unravel_index(i, shape0)[outer] for i in lost}
    survivors = [c for c in range(shape0[outer]) if c not in dropped]
    out = []
    for q in pod_ranks:
        coord = survivors[q]
        out.extend(i for i in range(math.prod(shape0))
                   if np.unravel_index(i, shape0)[outer] == coord)
    return sorted(out)


def _post_commit_faults(ckpt, plan: FaultPlan, ckpt_dir: str,
                        step: int) -> None:
    """Apply any corrupt_leaf fault scheduled for ``step`` — AFTER the
    async commit lands (wait), so the crc machinery (not the atomic
    rename) is what must catch it."""
    leaf = plan.corrupt_at(step)
    if leaf is not None:
        ckpt.wait()
        p = corrupt_leaf_file(ckpt_dir, step, leaf)
        print(f"fault: corrupted {p} after commit "
              f"(restore must fall back via crc32)", flush=True)


def main(argv=None):
    from repro.comm import strategies_for
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    # strategy surface: choices DERIVE from the train_step registry, so a
    # new registration is immediately drivable (and testable) from here
    ap.add_argument("--gradsync", default="native",
                    choices=list(strategies_for("train_step")),
                    help="gradient-sync / parameter-layout strategy "
                         "(registry-derived; 'auto' = cost model)")
    ap.add_argument("--gradsync-buckets", type=int, default=0,
                    help="bucket count K; 0 = cost-model auto")
    ap.add_argument("--fsdp-prefetch", type=int, default=0,
                    help="lane_zero3 gather blocks B; 0 = auto, "
                         "-1 = blocking negative control")
    ap.add_argument("--fsdp-regather", action="store_true",
                    help="lane_zero3 backward re-gather: re-run each "
                         "layer's weight gather in the backward under "
                         "remat (backward residuals stay 1/p)")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="gradient-accumulation microbatches per step "
                         "(0 = off); the LOCAL batch must divide by it")
    ap.add_argument("--accum-dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="microbatch gradient accumulator precision")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="tensor-parallel degree: pins the mesh 'model' "
                         "axis to this size; MLP activation collectives "
                         "route through model-axis (collective, "
                         "strategy) cells (1 = off)")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="MoE expert parallelism: token routing as the "
                         "decomposed moe_route alltoall over the batch "
                         "axes; under lane_zero3 the expert weights "
                         "live in a never-gathered E/p local master")
    ap.add_argument("--ep-blocks", type=int, default=1,
                    help="capacity-dim pipeline depth of the routing "
                         "alltoall (block j+1's dispatch overlaps block "
                         "j's expert FFN; 1 = sequential)")
    ap.add_argument("--pods", type=int, default=0,
                    help="pod (lane) axis size; 0 = auto (lane_zero3 "
                         "gets 2 when devices allow, else 1)")
    ap.add_argument("--lose-chips", default="",
                    help="comma-separated flat device indices to treat "
                         "as lost: re-plan the mesh around them "
                         "(elastic restart on survivors)")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault injection: "
                         "'kind@step[-until][:k=v,...];...' (kinds "
                         "pod_slow/pod_lost/ckpt_io/corrupt_leaf, see "
                         "runtime.faults) or 'seed:<n>' for a seeded "
                         "random plan")
    ap.add_argument("--tune", action="store_true",
                    help="probe the live topology's collective timings "
                         "before training (repro.tuning): measured "
                         "costs then outrank the closed-form model in "
                         "auto dispatch; results merge into the cache")
    ap.add_argument("--tuning-cache", default="",
                    help="timing-cache path (default: tuning_cache.json "
                         "inside --ckpt when one is set); restored "
                         "entries feed dispatch without re-probing")
    ap.add_argument("--quorum-staleness", type=int, default=2,
                    help="K: consecutive steps a pod may be masked out "
                         "of the quorum before DEGRADED escalates to "
                         "RESTART")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="in-process elastic restarts before giving up")
    args = ap.parse_args(argv)

    cfg = resolve(args.arch, smoke=args.smoke)
    mesh0 = make_mesh_auto(args.batch,
                           _resolve_pods(args.pods, args.gradsync),
                           tp=args.model_parallel)
    if args.fault_plan.startswith("seed:"):
        num_pods0 = mesh0.devices.shape[_outer_axis(mesh0)]
        plan = FaultPlan.generate(int(args.fault_plan[len("seed:"):]),
                                  args.steps, num_pods0)
        print(f"fault plan (seeded): {plan.faults}")
    else:
        plan = FaultPlan.parse(args.fault_plan)
    lost = set()
    if args.lose_chips:
        lost = {int(x) for x in args.lose_chips.split(",") if x != ""}

    # the recovery-ladder attempt loop: each RESTART returns the lost
    # pods' ORIGINAL-mesh device indices and the next attempt replans —
    # exactly the --lose-chips path, so the in-process restart is
    # bit-identical to a fresh launch on the survivors
    for attempt in range(args.max_restarts + 1):
        rc, more = _run_attempt(args, cfg, plan, mesh0, sorted(lost))
        if more is None:
            return rc
        lost |= set(more)
        print(f"restart {attempt + 1}/{args.max_restarts}: re-planning "
              f"around lost devices {sorted(lost)}", flush=True)
    print(f"giving up after {args.max_restarts} restarts",
          file=sys.stderr, flush=True)
    return 1


def _tuning_cache_path(args) -> str:
    """Where the timing cache lives: ``--tuning-cache`` if given, else
    beside the checkpoints, else nowhere ("")."""
    import os
    from repro.tuning import DEFAULT_CACHE_NAME
    return args.tuning_cache or (
        os.path.join(args.ckpt, DEFAULT_CACHE_NAME) if args.ckpt else "")


def _setup_tuner(args, mesh, ba):
    """Restore/probe the timing cache and return a Tuner (or None).

    The cache rides in the checkpoint directory by default
    (``--tuning-cache`` overrides), so a resumed run re-ranks with the
    same measured costs it committed to — measure once, then commit.
    A missing or corrupt cache degrades to the closed-form model; with
    ``--tune`` the probe fills (only) unmeasured cells — the ladder
    sweep PLUS the persisted cache-miss worklist (payload sizes a
    previous run's dispatch asked for but the cache could not answer) —
    and the merged table is saved back atomically, consuming the
    worklist.
    """
    from repro.core.lane import LaneTopology
    from repro.tuning import (DEFAULT_LADDER, SMOKE_LADDER, TimingTable,
                              Tuner, load_timing_table_or_none,
                              probe_cells, save_timing_table)
    from repro.tuning.probe import probe_worklist
    from repro.tuning.store import load_misses
    cache_path = _tuning_cache_path(args)
    if not cache_path and not args.tune:
        return None
    table = (load_timing_table_or_none(cache_path)
             if cache_path else None) or TimingTable()
    if args.tune:
        topo = LaneTopology(node_axes=ba[1:], lane_axis=ba[0])
        ladder = SMOKE_LADDER if args.smoke else DEFAULT_LADDER
        probe_cells(mesh, topo, ladder=ladder, table=table)
        worklist = load_misses(cache_path) if cache_path else []
        if worklist:
            probed = probe_worklist(mesh, topo, worklist, table=table)
            print(f"tuning worklist: {probed}/{len(worklist)} recorded "
                  f"misses probed", flush=True)
        if cache_path:
            save_timing_table(cache_path, table)
            print(f"tuning cache committed: {cache_path} "
                  f"({len(table)} cells)", flush=True)
    return Tuner(table) if len(table) else None


def _adopt_fitted_hw(tuner) -> None:
    """Install the timing-cache-fitted HW constants BEFORE step building.

    When a run has a measured timing table (a restored cache or a fresh
    ``--tune`` probe), the closed-form cost model should price with
    constants fitted to THAT topology (tuning.fit.fit_hw), not the
    shipped defaults — and the install must happen before
    build_train_step_lane / init_lane_train_state so the K/B layout
    resolutions the run (and its checkpoint geometry) commit to are
    priced against the same constants end to end.  Unfittable tables
    (too few cells) degrade to the defaults, loudly."""
    if tuner is None:
        return
    from repro.core.costmodel import set_hw
    from repro.tuning.fit import fit_hw
    try:
        fit = fit_hw(tuner.table)
    except ValueError as e:
        print(f"fitted-HW adoption skipped ({e}); cost model keeps the "
              f"shipped constants", flush=True)
        return
    set_hw(fit.hw)
    print(f"cost-model HW adopted from measured timing cache: "
          f"{fit.num_cells} cells, residual rms "
          f"{fit.residual_rms_us:.1f}us / max {fit.residual_max_us:.1f}us",
          flush=True)


def _commit_tuner_misses(args, tuner) -> None:
    """Persist the misses dispatch accumulated this run so the next
    ``--tune`` launch probes exactly those cells (the "commit" half of
    measure-once-then-commit for payloads the ladder never covered).
    Best-effort: a failed write must not fail a finished run."""
    from repro.tuning import save_timing_table
    cache_path = _tuning_cache_path(args)
    if not (cache_path and tuner is not None and tuner.misses):
        return
    try:
        save_timing_table(cache_path, tuner.table, misses=tuner.misses)
        uniq = len(dict.fromkeys(tuple(m) for m in tuner.misses))
        print(f"tuning misses committed: {uniq} cells queued for the "
              f"next --tune pass ({cache_path})", flush=True)
    except OSError as e:
        print(f"WARNING: tuning miss commit failed: {e}",
              file=sys.stderr, flush=True)


def _run_attempt(args, cfg, plan: FaultPlan, mesh0, lost):
    """One attempt of the run on the mesh that survives ``lost``.

    Returns (rc, None) when the run completed (or legitimately stopped),
    or (None, new_lost_flat_indices) when the health ladder hit RESTART
    — the caller replans and tries again.
    """
    mesh = mesh0
    if lost:
        em = plan_elastic_mesh(mesh0.axis_names, mesh0.devices.shape, lost)
        mesh = em.make()
        print(f"elastic mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}"
              f" (lost {em.lost})")
    ba = batch_axes(mesh)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape, remat=args.remat,
                    gradsync=args.gradsync,
                    gradsync_buckets=args.gradsync_buckets,
                    fsdp_prefetch=args.fsdp_prefetch,
                    fsdp_regather=args.fsdp_regather,
                    microbatch=args.microbatch,
                    accum_dtype=args.accum_dtype,
                    model_parallel=args.model_parallel,
                    expert_parallel=args.expert_parallel,
                    ep_blocks=args.ep_blocks)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)

    # measured-cost tuning (repro.tuning): restore the cache living
    # beside the checkpoints, optionally probe this topology (--tune;
    # measure-once — already-measured cells are skipped), and hand the
    # tuner to the step builder so auto dispatch ranks by measured cost.
    # The fitted-HW install happens HERE, before any step/layout
    # building: installing later would desync the K/B layout resolutions
    # the checkpoint geometry commits to from the constants pricing them.
    tuner = _setup_tuner(args, mesh, ba)
    _adopt_fitted_hw(tuner)

    # step first (it validates strategy × topology, e.g. lane_zero3 on a
    # single-batch-axis mesh), then the layout-matched master state
    step, comm = build_train_step_lane(cfg, run, opt_cfg, mesh, None,
                                       tuner=tuner)
    params0 = init_model(jax.random.PRNGKey(args.seed), cfg)
    st = init_lane_train_state(cfg, run, mesh, params0, comm=comm)
    pshard, oshard = st.to_shardings(mesh)

    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt, layout=st.ckpt_layout) \
        if args.ckpt else None
    if args.ckpt and latest_step(args.ckpt) is not None:
        # the host-side st trees are only the shape/layout targets here —
        # don't device_put a full init state just to overwrite it.
        # restore_lane_train_state handles BOTH same-kind restores and
        # cross-layout ones (a lane_zero3 checkpoint resuming under
        # lane_zero1 or a replicated strategy, and back) through the
        # canonical flat order — and falls back to the newest committed
        # step whose crc32s verify when the latest one rotted on disk
        (params, opt_state), start_step = restore_lane_train_state(
            args.ckpt, cfg, run, mesh, st,
            shardings=(pshard, oshard))
        print(f"resumed from step {start_step} "
              f"(layout {st.ckpt_layout.kind})")
    else:
        params = jax.tree.map(jax.device_put, st.params, pshard)
        opt_state = jax.tree.map(jax.device_put, st.opt_state, oshard)

    # fault/quorum machinery: the watchdog folds heartbeats (driven by
    # the fault plan; on a real fleet, by per-host progress counters)
    # into the 0/1 contributing mask, and the health monitor runs the
    # HEALTHY → DEGRADED → RESTART ladder on it.  Strategies without a
    # quorum grad-sync cannot form a step minus a pod, so any masked
    # pod escalates straight to RESTART (can_degrade=False).
    num_pods = mesh.devices.shape[_outer_axis(mesh)]
    needs_mask = bool(getattr(step, "needs_quorum_mask", False))
    watch = Watchdog(num_pods) if (plan or needs_mask) else None
    health = HealthMonitor(num_pods,
                           staleness_limit=args.quorum_staleness,
                           can_degrade=needs_mask) if watch else None

    dspec = P(ba)
    in_specs = [st.pspecs, st.ospecs, dspec, dspec, None]
    if needs_mask:
        in_specs.append(P())           # quorum mask: replicated
    step_fn = jax.jit(
        jax.shard_map(step, mesh=mesh,
                      in_specs=tuple(in_specs),
                      out_specs=(P(), st.pspecs, st.ospecs),
                      check_vma=False),
        donate_argnums=(0, 1))

    loader = make_loader(cfg, args.seq, args.batch, seed=args.seed)

    # SIGTERM (preemption) → emergency checkpoint at the next step boundary
    terminate = {"now": False}
    old = signal.signal(signal.SIGTERM,
                        lambda *_: terminate.__setitem__("now", True))

    t0 = time.time()
    losses = []
    done = start_step        # last COMPLETED step count (emergency save)
    saved = start_step       # largest step known committed
    restart_lost = None      # set when the health ladder demands RESTART
    try:
        for s in range(start_step, args.steps):
            mask = None
            if watch is not None:
                for pod in set(range(num_pods)) \
                        - set(plan.pods_down(s, num_pods)):
                    watch.heartbeat(pod, s)
                mask = watch.mask(s)
                state = health.observe(s, mask)
                if state == RESTART:
                    restart_lost = _restart_flat_indices(
                        mesh0, lost, health.restart_pods())
                    break
                if state == DEGRADED:
                    rows = args.batch // num_pods
                    for pod in watch.stale(s):
                        # the dropped rows are a pure function of
                        # (seed, step, row range) — ShardedLoader
                        # .batch_slice regenerates exactly them
                        print(f"degraded step {s}: pod {pod} masked; "
                              f"rows [{pod * rows}, {(pod + 1) * rows})"
                              f" dropped, replayable from (seed="
                              f"{args.seed}, step={s})", flush=True)
            toks, labels = loader.batch_at(s)
            call = [params, opt_state, jnp.asarray(toks),
                    jnp.asarray(labels), None]
            if needs_mask:
                call.append(jnp.asarray(
                    mask if mask is not None
                    else np.ones((num_pods,), np.float32)))
            loss, params, opt_state = step_fn(*call)
            done = s + 1     # only after the step returned — a raise or
            #                  SIGTERM mid-step must not claim step s
            if s % args.log_every == 0 or s == args.steps - 1:
                lv = float(loss)
                losses.append(lv)
                dt = time.time() - t0
                tps = (s - start_step + 1) * args.batch * args.seq / dt
                print(f"step {s:5d}  loss {lv:8.4f}  tok/s {tps:9.0f}",
                      flush=True)
            if ckpt and done % args.ckpt_every == 0:
                ckpt.save(done, (params, opt_state),
                          attempt_hook=plan.ckpt_attempt_hook(done))
                saved = done
                _post_commit_faults(ckpt, plan, args.ckpt, done)
            if terminate["now"]:
                print("SIGTERM: emergency checkpoint")
                break
    finally:
        signal.signal(signal.SIGTERM, old)
        # whether the loop is already unwinding an exception MUST be read
        # before the except below makes it the "current" exception
        unwinding = sys.exc_info()[1] is not None
        if ckpt:
            try:
                if done > saved and _tree_alive((params, opt_state)):
                    ckpt.save(done, (params, opt_state),
                              attempt_hook=plan.ckpt_attempt_hook(done))
                    saved = done
                    _post_commit_faults(ckpt, plan, args.ckpt, done)
                elif done > saved:
                    # a raise INSIDE step done+1 deleted the state (it was
                    # donated into the failing call): nothing to save —
                    # say so instead of crashing on dead buffers
                    print(f"emergency checkpoint skipped: state of step "
                          f"{done} was donated into the failing step; "
                          f"latest committed checkpoint is step {saved}",
                          file=sys.stderr, flush=True)
                ckpt.wait()
            except BaseException as e:  # noqa: BLE001
                # surface the writer failure; only re-raise when it would
                # not mask the exception already unwinding the loop
                print(f"CHECKPOINT ERROR: save at step {done} failed: "
                      f"{e!r}", file=sys.stderr, flush=True)
                if not unwinding:
                    raise
    _commit_tuner_misses(args, tuner)
    if restart_lost is not None:
        print(f"RESTART at step {done}: emergency checkpoint committed, "
              f"shrinking around pods {health.restart_pods()}", flush=True)
        if not args.ckpt:
            print("WARNING: no --ckpt; the restarted attempt re-inits "
                  "from scratch", file=sys.stderr, flush=True)
        return None, restart_lost
    if start_step >= args.steps:
        # resuming a finished run: the loop never ran — nothing to
        # report (and nothing was checkpointed above)
        print(f"nothing to do: resumed at step {start_step} >= "
              f"--steps {args.steps}")
        return 0, None
    if not losses:
        # stopped (SIGTERM) before the first log boundary — real work
        # may still have been checkpointed above
        print(f"stopped at step {done} before the first log boundary")
        return 0, None
    if len(losses) >= 2 and losses[-1] >= losses[0]:
        print(f"WARNING: loss did not decrease ({losses[0]:.3f} → "
              f"{losses[-1]:.3f})")
    else:
        print(f"loss {losses[0]:.4f} → {losses[-1]:.4f}  OK")
    return 0, None


if __name__ == "__main__":
    sys.exit(main())
