import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (device count locks at
# first backend init).  This module is the ONLY place the flag is set —
# tests/benches see the real single CPU device.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating any real buffers
(ShapeDtypeStruct inputs only):

  * compiled.memory_analysis()  — proof the cell fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * a parse of the optimized HLO summing every collective's wire bytes,
    split ICI (intra-pod) vs DCN (cross-pod)  — the §Roofline collective
    term (cost_analysis does not include collectives)

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k [--multi]
  python -m repro.launch.dryrun --all          # every cell, subprocess each
  python -m repro.launch.dryrun --list
Results land in runs/dryrun/{single,multi}/<arch>__<shape>.json.
"""
import argparse
import dataclasses
import json
import pathlib
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import resolve, all_archs, cells, SHAPES, RunConfig
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import init_model, init_cache
from repro.models.transformer import ServeState
from repro.optim import AdamWConfig, adamw_init
from repro.launch.mesh import make_production_mesh, batch_axes, mesh_sizes
from repro.launch import sharding as sh
from repro.launch.steps import build_train_step, build_prefill_step, \
    build_decode_step
from repro.launch import hlo_stats

RUNS = pathlib.Path(__file__).resolve().parents[3] / "runs" / "dryrun"


# ---------------------------------------------------------------------------
# per-cell planning
# ---------------------------------------------------------------------------

def _batch_axes_for(mesh, plan_name: str):
    """tp0 plan: the model axis joins the batch product (no TP)."""
    ba = batch_axes(mesh)
    if plan_name == "tp0":
        ba = (*ba, "model")
    return ba


def plan(cfg: ModelConfig, shape: ShapeConfig, mesh, *, micro_override=0,
         plan_name="default") -> RunConfig:
    nb = 1
    for a in _batch_axes_for(mesh, plan_name):
        nb *= mesh_sizes(mesh)[a]
    fsdp = cfg.param_count() > 10e9 or plan_name == "tp0"
    micro = 0
    if shape.kind == "train":
        b_loc = max(shape.global_batch // nb, 1)
        # per-µstep token budget: wide models halve it (activation bytes
        # scale with d_model; dbrx/qwen at 8192 tokens/µstep blow HBM)
        tok_budget = 8192 if cfg.d_model <= 4096 else 4096
        rows = max(1, tok_budget // shape.seq_len)
        micro = max(1, b_loc // rows)
    if micro_override:
        micro = micro_override
    # the sharding PLAN rides its own field; gradsync goes through a real
    # registry strategy ("auto" = cost-model dispatch) so the unknown-
    # strategy validation of RunConfig.__post_init__ stays armed
    return RunConfig(model=cfg, shape=shape, fsdp=fsdp,
                     remat="full" if shape.kind == "train" else "none",
                     microbatch=micro, gradsync="auto", plan=plan_name)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, ba=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, T = shape.global_batch, shape.seq_len
    if ba is None:
        ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh_sizes(mesh)[a]
    if B % nb:                       # tiny-batch cells: don't shard batch
        ba = ()
    tok_sh = NamedSharding(mesh, P(ba or None, None))
    emb_sh = NamedSharding(mesh, P(ba or None, None, None))
    i32 = jnp.int32
    d = cfg.d_model

    def tokens(n, t):
        return jax.ShapeDtypeStruct((n, t), i32, sharding=tok_sh)

    extra = None
    t_text = T
    if cfg.family == "vlm":
        t_text = T - cfg.vision_tokens
        extra = jax.ShapeDtypeStruct((B, cfg.vision_tokens, d),
                                     jnp.dtype(cfg.dtype), sharding=emb_sh)
    elif cfg.family == "audio":
        extra = jax.ShapeDtypeStruct((B, cfg.encoder_seq, d),
                                     jnp.dtype(cfg.dtype), sharding=emb_sh)
    if shape.kind == "train":
        return {"tokens": tokens(B, t_text), "labels": tokens(B, t_text),
                "extra": extra}
    if shape.kind == "prefill":
        return {"tokens": tokens(B, t_text), "extra": extra}
    return {"token": tokens(B, 1), "extra": extra}       # decode


def _cache_seq_spec(shape: ShapeConfig, mesh):
    """KV-cache seq dim: "model"; for tiny-batch long-context cells the
    batch axes join in (2-D sequence sharding) so B=1 doesn't strand the
    data axis."""
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh_sizes(mesh)[a]
    if shape.global_batch < nb:
        return tuple([*ba, "model"]), True
    return "model", False


# ---------------------------------------------------------------------------
# lowering per kind
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               micro_override: int = 0, plan_name: str = "default",
               accum_bf16: bool = False):
    # mesh context: with_sharding_constraint inside the step functions uses
    # bare PartitionSpecs (spec item 3: ``with mesh: lowered = jax.jit(...)``)
    from repro.models.transformer import activation_batch_axes
    ba = _batch_axes_for(mesh, plan_name)
    nb = 1
    for a in ba:
        nb *= mesh_sizes(mesh)[a]
    pin = ba if shape.global_batch % nb == 0 else None
    # residual/FFN feature dims shard over "model" between layers for the
    # full-sequence kinds (train backward saves, prefill MoE buffers);
    # decode works on length-1 tensors where the extra gathers cost more
    # than the bytes
    d_axis = ("model" if plan_name == "default"
              and shape.kind in ("train", "prefill")
              and cfg.d_model % mesh_sizes(mesh).get("model", 1) == 0
              else None)
    kv = None
    if shape.kind in ("prefill", "decode"):
        seq_spec, seq2d = _cache_seq_spec(shape, mesh)
        kv = ((None if seq2d else ba), seq_spec)
    with mesh, activation_batch_axes(pin, d_axis, kv=kv):
        return _lower_cell(cfg, shape, mesh, micro_override, plan_name,
                           accum_bf16)


def _lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                micro_override: int = 0, plan_name: str = "default",
                accum_bf16: bool = False):
    run = plan(cfg, shape, mesh, micro_override=micro_override,
               plan_name=plan_name)
    ins = input_specs(cfg, shape, mesh,
                      ba=_batch_axes_for(mesh, plan_name))
    pshapes = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    pspecs = sh.param_pspecs(pshapes, cfg, mesh, fsdp=run.fsdp,
                             tp=plan_name != "tp0")
    p_sds = sh.sds(pshapes, pspecs, mesh)
    B, T = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, pshapes)
        ospecs = sh.opt_pspecs(pspecs)
        o_sds = sh.sds(opt_shapes, ospecs, mesh)
        # --accum-bf16 forces bf16; otherwise defer to run.accum_dtype
        # (build_train_step resolves None from the RunConfig)
        step = build_train_step(cfg, run, AdamWConfig(),
                                _batch_axes_for(mesh, plan_name),
                                accum_dtype=jnp.bfloat16 if accum_bf16
                                else None)
        jitted = jax.jit(
            step,
            in_shardings=(sh.to_shardings(pspecs, mesh),
                          sh.to_shardings(ospecs, mesh),
                          ins["tokens"].sharding, ins["labels"].sharding,
                          None if ins["extra"] is None
                          else ins["extra"].sharding),
            out_shardings=(NamedSharding(mesh, P()),
                           sh.to_shardings(pspecs, mesh),
                           sh.to_shardings(ospecs, mesh)),
            donate_argnums=(0, 1))
        args = (p_sds, o_sds, ins["tokens"], ins["labels"], ins["extra"])
        lowered = jitted.lower(*args)
        return lowered, run

    seq_spec, seq2d = _cache_seq_spec(shape, mesh)
    ba = batch_axes(mesh)
    bspec = None if seq2d else ba   # B=1: don't shard batch

    def cache_shapes(max_seq):
        return jax.eval_shape(
            lambda: init_cache(cfg, B, max_seq, dtype=jnp.dtype(cfg.dtype)))

    def cache_specs(cshapes):
        def rule(path, leaf):
            ps = sh._path_str(path)
            nd = len(leaf.shape)
            spec = [None] * nd
            if ps in ("k", "v") or ps.endswith("/k") or ps.endswith("/v"):
                spec[nd - 4] = bspec
                spec[nd - 3] = seq_spec
            elif "conv" in ps:
                spec[1] = bspec
                if "conv_x" in ps:
                    spec[-1] = "model"
            elif ps.endswith("ssm"):
                spec[1] = bspec
                spec[2] = "model"
            return P(*spec)
        specs = jax.tree_util.tree_map_with_path(rule, cshapes)
        return sh.sanitize_specs(cshapes, specs, mesh)

    if shape.kind == "prefill":
        t_text = ins["tokens"].shape[1]
        cshapes = cache_shapes(T)
        cspecs = cache_specs(cshapes)
        c_sds = sh.sds(cshapes, cspecs, mesh)
        step = build_prefill_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(sh.to_shardings(pspecs, mesh),
                          ins["tokens"].sharding,
                          sh.to_shardings(cspecs, mesh),
                          None if ins["extra"] is None
                          else ins["extra"].sharding),
            donate_argnums=(2,))
        lowered = jitted.lower(p_sds, ins["tokens"], c_sds, ins["extra"])
        return lowered, run

    # decode: cache holds seq_len context, one new token
    cshapes = cache_shapes(T)
    cspecs = cache_specs(cshapes)
    c_sds = sh.sds(cshapes, cspecs, mesh)
    lsharding = NamedSharding(mesh, P(bspec))
    l_sds = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=lsharding)
    enc_sds = None
    if cfg.family == "audio":
        ekv = jax.eval_shape(
            lambda: {"k": jnp.zeros((cfg.num_layers, B, cfg.encoder_seq,
                                     cfg.num_kv_heads, cfg.hd()),
                                    jnp.dtype(cfg.dtype)),
                     "v": jnp.zeros((cfg.num_layers, B, cfg.encoder_seq,
                                     cfg.num_kv_heads, cfg.hd()),
                                    jnp.dtype(cfg.dtype))})
        espec = jax.tree.map(lambda _: P(None, bspec, None, None, None),
                             jax.tree.map(lambda x: 0, ekv))
        espec = {"k": P(None, bspec, None, None, None),
                 "v": P(None, bspec, None, None, None)}
        enc_sds = sh.sds(ekv, espec, mesh)
    state_sds = ServeState(cache=c_sds, length=l_sds, enc_kv=enc_sds)
    state_shardings = ServeState(
        cache=sh.to_shardings(cspecs, mesh), length=lsharding,
        enc_kv=None if enc_sds is None else
        jax.tree.map(lambda s: s.sharding, enc_sds))
    step = build_decode_step(cfg)
    jitted = jax.jit(step,
                     in_shardings=(sh.to_shardings(pspecs, mesh),
                                   ins["token"].sharding, state_shardings),
                     donate_argnums=(2,))
    lowered = jitted.lower(p_sds, ins["token"], state_sds)
    return lowered, run


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * _DTYPE_BYTES[dt]
    return total


def _line_group(line: str, pod_size: int):
    return hlo_stats.group_info(line, pod_size)


_CONV_RE = re.compile(r"=\s*f32\[([\d,]+)\][^\s]*\s+"
                      r"(?:convert|fusion)\(")


def _f32_mirror_bytes(hlo: str, floor: int = 256 * 2**20) -> int:
    """XLA:CPU computes dots through fp32 and hoists whole-stack operand
    conversions out of loops, keeping fp32 MIRRORS of large bf16 buffers
    (KV-cache stacks, MoE buffers) that do not exist on TPU, where the MXU
    consumes bf16 directly.  Sum the distinct ≥256 MB fp32 convert outputs
    so the roofline/memory report can state a TPU-adjusted figure."""
    seen: dict[tuple, int] = {}
    for line in hlo.splitlines():
        if "f32[" not in line or ("convert" not in line
                                  and "wrapped_convert" not in line):
            continue
        m = _CONV_RE.search(line)
        if not m:
            continue
        dims = tuple(int(x) for x in m.group(1).split(",") if x)
        b = 4
        for d in dims:
            b *= d
        if b >= floor:
            seen[dims] = b
    return int(sum(seen.values()))


def collect_collectives(hlo: str, *, pod_size: int = 256) -> dict:
    """Sum wire bytes per collective kind from optimized HLO (per device).

    Wire model (ring algorithms, per participating device):
      all-reduce 2(g-1)/g·b   all-gather (g-1)·b_in ≈ (g-1)/g·b_out
      reduce-scatter (g-1)/g·b_in   all-to-all (g-1)/g·b   permute b
    where b is the op's result byte size on this device.
    """
    per_kind: dict[str, dict] = {}
    dcn_bytes = 0.0
    ici_bytes = 0.0
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        gsz, dcn = _line_group(line, pod_size)
        g = gsz or 2
        if kind == "all-reduce":
            wire = 2 * (g - 1) / g * b
        elif kind == "all-gather":
            wire = (g - 1) / g * b
        elif kind == "reduce-scatter":
            wire = (g - 1) / g * b          # b here = input tuple size
        elif kind == "all-to-all":
            wire = (g - 1) / g * b
        else:                                # collective-permute
            wire = float(b)
        rec = per_kind.setdefault(kind, {"count": 0, "bytes": 0.0,
                                         "wire_bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += b
        rec["wire_bytes"] += wire
        if dcn:
            dcn_bytes += wire
        else:
            ici_bytes += wire
    return {"per_kind": per_kind,
            "total_bytes": sum(r["bytes"] for r in per_kind.values()),
            "total_wire_bytes": sum(r["wire_bytes"]
                                    for r in per_kind.values()),
            "dcn_wire_bytes": dcn_bytes, "ici_wire_bytes": ici_bytes}


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path | None = None, *,
             micro_override: int = 0, plan_name: str = "default",
             tag: str = "", accum_bf16: bool = False) -> dict:
    cfg = resolve(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, run = lower_cell(cfg, shape, mesh,
                              micro_override=micro_override,
                              plan_name=plan_name, accum_bf16=accum_bf16)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)}
    except Exception as e:  # noqa: BLE001
        mem_d = {"error": str(e)[:200]}

    try:
        cost = dict(compiled.cost_analysis())
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # noqa: BLE001
        cost = {"error": str(e)[:200]}

    hlo = compiled.as_text()
    colls = collect_collectives(hlo)
    f32_mirror = _f32_mirror_bytes(hlo)
    try:
        stats = hlo_stats.analyze(hlo)
        stats.pop("coll", None)
    except Exception as e:  # noqa: BLE001
        stats = {"error": str(e)[:300]}

    nchips = 512 if multi_pod else 256
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": nchips,
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
        "fsdp": run.fsdp, "microbatch": run.microbatch, "remat": run.remat,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d,
        "f32_mirror_bytes": f32_mirror,
        "cost_analysis": cost,
        "collectives": colls,          # bodies-once view (cross-check)
        "hlo_stats": stats,            # trip-count-corrected totals
        "hlo_bytes": len(hlo),
    }
    result["plan"] = run.plan
    if out_dir is not None:
        import gzip
        out_dir.mkdir(parents=True, exist_ok=True)
        stem = f"{arch}__{shape_name}" + (f"__{tag}" if tag else "")
        path = out_dir / f"{stem}.json"
        path.write_text(json.dumps(result, indent=1))
        # keep the optimized HLO so roofline/perf iterations can re-analyze
        # without recompiling
        with gzip.open(out_dir / f"{stem}.hlo.gz", "wt") as f:
            f.write(hlo)
        result["json"] = str(path)
    return result


def list_cells():
    rows = []
    for a in all_archs():
        cfg = resolve(a)
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if s == "long_500k" and not cfg.subquadratic:
                rows.append((a, s, "SKIP (full attention; DESIGN.md §4)"))
            else:
                rows.append((a, s, "run"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--accum-bf16", action="store_true")
    ap.add_argument("--plan", default="default", choices=["default", "tp0"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    if args.list:
        for a, s, st in list_cells():
            print(f"{a:28s} {s:12s} {st}")
        return 0

    if args.all:
        fails = []
        meshes = [False, True] if args.both_meshes else [args.multi]
        for multi in meshes:
            sub = RUNS / ("multi" if multi else "single")
            for a, s, st in list_cells():
                if st != "run":
                    continue
                if args.skip_existing and (sub / f"{a}__{s}.json").exists():
                    print(f"skip existing {a} {s}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s] + \
                      (["--multi"] if multi else [])
                print(f"=== {a} {s} {'multi' if multi else 'single'} ===",
                      flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=7200)
                tail = (r.stdout + r.stderr).strip().splitlines()[-8:]
                print("\n".join(tail), flush=True)
                if r.returncode != 0:
                    fails.append((a, s, multi))
        print(f"\nFAILED CELLS: {fails if fails else 'none'}")
        return len(fails)

    out = RUNS / ("multi" if args.multi else "single")
    res = run_cell(args.arch, args.shape, args.multi, out,
                   micro_override=args.microbatch, plan_name=args.plan,
                   tag=args.tag, accum_bf16=args.accum_bf16)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("hlo_bytes",)}, indent=1))
    print(f"memory_analysis: {res['memory_analysis']}")
    print(f"cost_analysis flops={res['cost_analysis'].get('flops')}")
    print(f"collectives total wire bytes="
          f"{res['collectives']['total_wire_bytes']:.3e}")
    print("DRYRUN OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
