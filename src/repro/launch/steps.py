"""Step builders: train (GSPMD baseline + lane-decomposed variant), serve.

`build_train_step`   — jit/GSPMD end-to-end: the "native library" baseline.
                       Optional microbatch gradient accumulation (memory
                       control at 4k×256) — grads accumulate in fp32.
`build_train_step_lane` — the paper's technique as a first-class backend:
                       shard_map manual over the batch axes (pod, data),
                       GSPMD auto over "model"; gradient sync runs through
                       repro.optim.gradsync (native / lane / lane_int8 /
                       lane_zero1).  Params replicated over batch axes in
                       this path (≤ ~10B models).
`build_prefill_step` / `build_decode_step` — serving.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core import LaneTopology, optimal_prefetch_blocks
from repro.core.pipeline import pipelined_allgather_lane
from repro.models import init_model, loss_fn, prefill, decode_step
from repro.models.transformer import ShardedBlocks
from repro.optim import AdamWConfig, adamw_init, adamw_update, grad_sync
from repro.optim.gradsync import (
    _unflatten_bucket, _flatten_bucket, resolve_num_buckets,
    zero1_param_shard, zero1_unshard, zero3_unshard,
)
from .mesh import batch_axes


# ---------------------------------------------------------------------------
# GSPMD baseline train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, run: RunConfig,
                     opt: AdamWConfig, batch_axes: tuple[str, ...] = (),
                     accum_dtype=jnp.float32):
    """(params, opt_state, tokens, labels[, extra]) → (loss, params, opt).

    accum_dtype: microbatch gradient-accumulation precision.  bf16 halves
    the accumulator's HBM residency (the fp32 buffer is ~2 GB/chip for
    dbrx); stochastic error stays below the int8-DCN compression bound
    already accepted for the lane_int8 strategy.
    """

    def lf(p, tok, lab, ex):
        return loss_fn(p, cfg, tok, lab, extra_embeds=ex, remat=run.remat)

    def step(params, opt_state, tokens, labels, extra=None):
        mb = max(run.microbatch, 1)
        if mb == 1:
            loss, grads = jax.value_and_grad(lf)(params, tokens, labels, extra)
        else:
            B = tokens.shape[0]
            assert B % mb == 0, (B, mb)

            def sh(a):
                if a is None:
                    return None
                a = a.reshape(mb, B // mb, *a.shape[1:])
                if batch_axes:
                    # the (B,)→(mb, B/mb) reshape is ambiguous to GSPMD's
                    # propagation; without this constraint the per-µstep
                    # slice keeps the FULL local batch (verified: 16×
                    # activation memory on llama3.2 train_4k)
                    a = jax.lax.with_sharding_constraint(
                        a, P(None, batch_axes, *([None] * (a.ndim - 2))))
                return a

            tokens_mb, labels_mb = sh(tokens), sh(labels)
            extra_mb = sh(extra)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def acc(carry, xs):
                lsum, g = carry
                tok, lab = xs[0], xs[1]
                ex = xs[2] if len(xs) == 3 else None
                l, gi = jax.value_and_grad(lf)(params, tok, lab, ex)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g, gi)
                return (lsum + l, g), None

            xs = ((tokens_mb, labels_mb) if extra is None
                  else (tokens_mb, labels_mb, extra_mb))
            (lsum, gsum), _ = jax.lax.scan(acc, (0.0, g0), xs)
            loss = lsum / mb
            grads = jax.tree.map(lambda g: (g / mb), gsum)
        new_params, new_opt = adamw_update(opt, grads, opt_state, params)
        return loss, new_params, new_opt

    return step


# ---------------------------------------------------------------------------
# lane-decomposed train step (the paper's technique, swappable)
# ---------------------------------------------------------------------------

def build_train_step_lane(cfg: ModelConfig, run: RunConfig, opt: AdamWConfig,
                          mesh, param_specs):
    """Manual over batch axes; grad sync via repro.optim.gradsync.

    gradsync strategies: native | lane | lane_pipelined | lane_int8 |
    lane_zero1.  All lane strategies bucket the flat gradient vector
    (K = run.gradsync_buckets, 0 = cost-model auto) so the DCN lane hop of
    one bucket overlaps the ICI node collective of the next (§5 pipeline).
    lane_zero1 keeps grads + moments data-sharded through the optimizer and
    all-gathers the *updated parameters* (the paper's trailing AllGather
    moved past the update — same bytes, sharded optimizer memory); its
    shard layout is bucket-major, so param sharding/unsharding goes
    through gradsync.zero1_param_shard / zero1_unshard with the same K.
    lane_zero3 additionally keeps the scanned layer weights sharded 1/p
    per chip (zero3_shard_blocks layout) and re-gathers them LAYER BY
    LAYER inside the forward scan via the pipelined AG(lane)→AG(node)
    (core.pipeline.pipelined_allgather_lane), with a one-layer prefetch
    buffer so layer i+1's gather overlaps layer i's compute
    (run.fsdp_prefetch: 0 = cost-model block count, >0 = override,
    -1 = blocking negative control).  Gradients for the stack need no
    separate sync: the gather's AD transpose IS the lane_zero3
    reduce-scatter.
    """
    ba = batch_axes(mesh)
    if run.gradsync == "lane_zero3" and len(ba) < 2:
        # zero3 shards over the (lane × node) product and its gather
        # pipeline needs the two levels to be DISTINCT axes; there is no
        # sensible single-axis degradation (unlike the other strategies,
        # which fall back to native below)
        raise ValueError(
            "lane_zero3 needs distinct lane and node batch axes (a "
            "multi-pod mesh); use native or lane_zero1 on single-"
            f"batch-axis meshes (got batch axes {ba})")
    topo = LaneTopology(node_axes=ba[1:] or ba, lane_axis=ba[0]) \
        if len(ba) > 1 else LaneTopology(node_axes=(ba[0],), lane_axis=ba[0])
    # single-pod fallback: treat "data" as the lane axis with a trivial
    # node level — handled by strategy below
    single = len(ba) == 1
    strategy = run.gradsync

    def lf(p, tok, lab, ex):
        return loss_fn(p, cfg, tok, lab, extra_embeds=ex, remat=run.remat)

    if strategy == "lane_zero3":
        n_, N_ = topo.sizes(mesh)
        spec3 = zero3_layer_spec(cfg)
        B3 = resolve_prefetch_blocks(spec3.layer_elems, n_, N_,
                                     run.fsdp_prefetch)
        blocking = run.fsdp_prefetch == -1

        def gather_layer(x):
            full = (zero3_unshard(x, topo, B3) if blocking
                    else pipelined_allgather_lane(x, topo, num_blocks=B3))
            return unflatten_layer(full, spec3)

        def per_replica_zero3(params, opt_state, tokens, labels, extra=None):
            """lane_zero3 train step.

            params["blocks"] is this chip's shard — any shape reshapeable
            to (L, B·s), e.g. the local block of the host-side
            (L, B, n·N, s) layout from zero3_shard_blocks.  opt_state is
            the split {"rest", "blocks"} state of zero3_opt_init.  The
            returned params keep the blocks SHARDED (same shape as the
            input): ZeRO-3 never materializes full parameters outside the
            per-layer prefetch window.
            """
            # NOTE optimizer-semantics parity with lane_zero1, not native:
            # the flat sharded AdamW (_adamw_flat) does no global-norm
            # clipping (a true global norm needs an extra cross-shard
            # psum) and applies weight decay uniformly, incl. norm gains;
            # the rest-params clip by their own partial norm.  Exact-
            # native comparisons neutralize both (see the zero3 test
            # case); sharded clipping is a ROADMAP follow-up.
            bshape = params["blocks"].shape
            shards = params["blocks"].reshape(spec3.num_layers, -1)
            rest = {k: v for k, v in params.items() if k != "blocks"}

            def lf3(rest_p, sh):
                p = dict(rest_p)
                p["blocks"] = ShardedBlocks(sh, gather_layer,
                                            prefetch=not blocking)
                return lf(p, tokens, labels, extra)

            loss, (g_rest, g_sh) = jax.value_and_grad(
                lf3, argnums=(0, 1))(rest, shards)
            loss = jax.lax.pmean(loss, ba)
            # the gather's transpose already reduce-scattered g_sh over
            # (lane × node) — sum over replicas; only the mean is left
            g_sh = g_sh / _axprod(ba)
            g_rest = grad_sync(g_rest, topo, "lane",
                               num_buckets=run.gradsync_buckets)
            new_rest, new_opt_rest = adamw_update(
                opt, g_rest, opt_state["rest"], rest)
            ob = opt_state["blocks"]
            newp, nob = _adamw_flat(
                opt, g_sh.reshape(-1),
                {"m": ob["m"].reshape(-1), "v": ob["v"].reshape(-1),
                 "count": ob["count"]},
                shards.reshape(-1))
            new_params = dict(new_rest)
            new_params["blocks"] = newp.reshape(bshape)
            new_opt = {"rest": new_opt_rest,
                       "blocks": {"m": nob["m"].reshape(ob["m"].shape),
                                  "v": nob["v"].reshape(ob["v"].shape),
                                  "count": nob["count"]}}
            return loss, new_params, new_opt

        return per_replica_zero3, topo

    def per_replica(params, opt_state, tokens, labels, extra):
        loss, grads = jax.value_and_grad(lf)(params, tokens, labels, extra)
        loss = jax.lax.pmean(loss, ba)
        if single or strategy == "native":
            grads = jax.tree.map(
                lambda g: jax.lax.psum(g, ba) / _axprod(ba), grads)
            new_params, new_opt = adamw_update(opt, grads, opt_state, params)
            return loss, new_params, new_opt
        if strategy == "lane_zero1":
            total = sum(math.prod(p.shape)
                        for p in jax.tree.leaves(params))
            K = resolve_num_buckets(total, topo.n(), run.gradsync_buckets)
            shard_flat, spec = grad_sync(grads, topo, "lane_zero1",
                                         num_buckets=K)
            pflat, pspec = _flatten_bucket(params, pad_to=K * topo.n())
            mine = zero1_param_shard(pflat, topo, K)
            # sharded moments: opt_state here is the *sharded* flat state
            newp_shard, new_opt = _adamw_flat(opt, shard_flat, opt_state, mine)
            full = zero1_unshard(newp_shard, topo, K)
            new_params = _unflatten_bucket(full, pspec)
            return loss, new_params, new_opt
        grads = grad_sync(grads, topo, strategy,
                          num_buckets=run.gradsync_buckets)
        new_params, new_opt = adamw_update(opt, grads, opt_state, params)
        return loss, new_params, new_opt

    in_specs = (jax.tree.map(lambda s: _strip_batch(s, ba), param_specs),
                None, P(ba, None), P(ba, None), None)
    # NOTE: with auto={"model"} GSPMD still handles the TP dimension.
    return per_replica, topo


def _axprod(axes):
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def _strip_batch(spec, ba):
    return spec


def _adamw_flat(opt: AdamWConfig, g, state, p):
    """AdamW on a flat fp32 shard (ZeRO-1)."""
    from repro.optim.adamw import cosine_lr
    count = state["count"] + 1
    lr = cosine_lr(opt, count)
    m = opt.b1 * state["m"] + (1 - opt.b1) * g
    v = opt.b2 * state["v"] + (1 - opt.b2) * jnp.square(g)
    c1 = 1 - opt.b1 ** count.astype(jnp.float32)
    c2 = 1 - opt.b2 ** count.astype(jnp.float32)
    step = (m / c1) / (jnp.sqrt(v / c2) + opt.eps) + opt.weight_decay * p
    return p - lr * step, {"m": m, "v": v, "count": count}


def zero1_opt_init(params, topo_n: int, num_buckets: int = 0):
    """Flat sharded fp32 optimizer state for the lane_zero1 path.

    Pass ``run.gradsync_buckets`` as num_buckets: the shard size depends
    on the bucketed padding (K·n), so this MUST match the train step's
    override — resolve_num_buckets is deterministic, so the default 0
    (auto) agrees with the step's auto choice, but a nonzero override on
    one side only produces a shape mismatch inside the jitted step.
    """
    total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
    K = resolve_num_buckets(total, topo_n, num_buckets)
    padded = -(-total // (K * topo_n)) * (K * topo_n)
    sz = padded // topo_n
    return {"m": jnp.zeros((sz,), jnp.float32),
            "v": jnp.zeros((sz,), jnp.float32),
            "count": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# ZeRO-3 layer sharding (the lane_zero3 / FSDP path)
# ---------------------------------------------------------------------------
#
# The scanned layer stack params["blocks"] (every leaf (L, ...)) is
# flattened per layer into an (L, D) fp32 master copy, padded to
# D_pad = B·n·N·s, and each chip keeps the (L, B·s) stripe of the
# gradsync.zero3_param_shard layout.  The host-side array is shaped
# (L, B, n·N, s) so a plain NamedSharding P(None, None, (*node_axes,
# lane_axis), None) places exactly stripe (node_rank·N + lane_rank) on
# each chip — no host-side rank arithmetic.  Everything that both sides
# of the shard_map boundary must agree on (leaf order, D, B, s) is
# derived deterministically from the ModelConfig via zero3_layer_spec.

class Zero3LayerSpec:
    """Flat layout of ONE layer's parameter tree (derived via eval_shape,
    so it never materializes weights)."""

    def __init__(self, metas, treedef, layer_elems: int, num_layers: int):
        self.metas = metas              # ((shape[1:], dtype) per leaf)
        self.treedef = treedef
        self.layer_elems = layer_elems  # D: unpadded flat size per layer
        self.num_layers = num_layers


def zero3_layer_spec(cfg: ModelConfig) -> Zero3LayerSpec:
    abs_params = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))
    leaves, treedef = jax.tree.flatten(abs_params["blocks"])
    metas = tuple((tuple(l.shape[1:]), l.dtype) for l in leaves)
    elems = sum(math.prod(s) for s, _ in metas)
    return Zero3LayerSpec(metas, treedef, elems, leaves[0].shape[0])


def unflatten_layer(vec, spec: Zero3LayerSpec):
    """Padded flat fp32 layer vector -> the layer's parameter tree (leaves
    cast back to their stored dtypes)."""
    out, ofs = [], 0
    for shape, dtype in spec.metas:
        sz = math.prod(shape)
        out.append(vec[ofs:ofs + sz].reshape(shape).astype(dtype))
        ofs += sz
    return jax.tree.unflatten(spec.treedef, out)


def resolve_prefetch_blocks(layer_elems: int, n: int, N: int,
                            override: int = 0) -> int:
    """The B every lane_zero3 call site uses (shard layout, opt-state
    size, per-layer gather pipeline).  override > 0 wins; -1 (blocking
    negative control) gathers monolithically so B degenerates to 1;
    otherwise the cost model picks B from the DCN latency/bandwidth
    crossover on the per-chip stripe.  Capped so each block keeps at
    least one row per chip."""
    p = max(n * N, 1)
    if override > 0:
        b = override
    elif override < 0:
        b = 1
    else:
        b = optimal_prefetch_blocks(layer_elems * 4 / p)
    return max(1, min(b, max(1, layer_elems // p)))


def _flatten_blocks_layerwise(blocks, pad_to: int):
    leaves, _ = jax.tree.flatten(blocks)
    L = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(L, -1).astype(jnp.float32) for l in leaves], axis=1)
    pad = (-flat.shape[1]) % pad_to
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((L, pad), flat.dtype)], axis=1)
    return flat


def zero3_shard_blocks(blocks, n: int, N: int, fsdp_prefetch: int = 0):
    """Host-side: the (L, B, n·N, s) fp32 master layout of the stacked
    layer tree.  Place on the mesh with
    ``P(None, None, (*node_axes, lane_axis), None)`` and each chip's
    local block reshapes to the (L, B·s) shard the train step expects.
    Returns (array, B)."""
    leaves = jax.tree.leaves(blocks)
    L = leaves[0].shape[0]
    elems = sum(math.prod(l.shape[1:]) for l in leaves)
    B = resolve_prefetch_blocks(elems, n, N, fsdp_prefetch)
    p = n * N
    flat = _flatten_blocks_layerwise(blocks, pad_to=B * p)
    s = flat.shape[1] // (B * p)
    return flat.reshape(L, B, p, s), B


def zero3_opt_init(params, n: int, N: int, fsdp_prefetch: int = 0):
    """Split optimizer state for the lane_zero3 step: ordinary AdamW tree
    state for the replicated non-block params, flat sharded fp32 moments
    (in the zero3_shard_blocks layout) for the layer stack.  The B
    resolution MUST match the step's (resolve_prefetch_blocks is
    deterministic, so the default 0 agrees; pass the same
    run.fsdp_prefetch override on both sides)."""
    blocks = params["blocks"]
    rest = {k: v for k, v in params.items() if k != "blocks"}
    # derive the moment shape FROM zero3_shard_blocks (via eval_shape, no
    # weight materialization) so the layout invariant lives in one place
    shard = jax.eval_shape(
        lambda b: zero3_shard_blocks(b, n, N, fsdp_prefetch)[0], blocks)
    zeros = jnp.zeros(shard.shape, jnp.float32)
    return {"rest": adamw_init(rest),
            "blocks": {"m": zeros, "v": zeros,
                       "count": jnp.zeros((), jnp.int32)}}


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig):
    def step(params, tokens, cache, extra=None):
        return prefill(params, cfg, tokens, cache, extra_embeds=extra)
    return step


def build_decode_step(cfg: ModelConfig):
    def step(params, token, state):
        return decode_step(params, cfg, token, state)
    return step
