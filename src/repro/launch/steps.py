"""Step builders: train (GSPMD baseline + lane-decomposed variant), serve.

`build_train_step`   — jit/GSPMD end-to-end: the "native library" baseline.
                       Optional microbatch gradient accumulation (memory
                       control at 4k×256) — grads accumulate in fp32.
`build_train_step_lane` — the paper's technique as a first-class backend:
                       shard_map manual over the batch axes (pod, data),
                       GSPMD auto over "model"; all collectives run
                       through a repro.comm.LaneComm, and the per-strategy
                       step CONSTRUCTION dispatches through the same
                       registry (@register_impl("train_step", ...) below)
                       — no strategy if-chains.  Params replicated over
                       batch axes in the non-ZeRO flavors (≤ ~10B models).
`build_prefill_step` / `build_decode_step` — serving.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import (CommConfig, LaneComm, get_impl, register_impl,
                        register_param_layout)
from repro.configs.base import ModelConfig, RunConfig
from repro.core import LaneTopology
from repro.models import init_model, loss_fn, prefill, decode_step
from repro.models.blockstack import (
    ShardedStack, StackLayout, block_stack_spec,
    resolve_extras_prefetch_blocks, resolve_prefetch_blocks,
    shard_stack, split_params, stack_layout,
)
from repro.models.parallel import parallel_context
from repro.models.transformer import ShardedBlocks  # noqa: F401 (re-export)
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import global_norm
from repro.optim.gradsync import (
    _unflatten_bucket, _flatten_bucket, decay_mask_flat, resolve_num_buckets,
    zero1_param_shard, zero1_unshard, zero3_param_shard,
)
from .mesh import batch_axes


# ---------------------------------------------------------------------------
# GSPMD baseline train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, run: RunConfig,
                     opt: AdamWConfig, batch_axes: tuple[str, ...] = (),
                     accum_dtype=None):
    """(params, opt_state, tokens, labels[, extra]) → (loss, params, opt).

    accum_dtype: microbatch gradient-accumulation precision (None =
    ``run.accum_dtype``).  bf16 halves the accumulator's HBM residency
    (the fp32 buffer is ~2 GB/chip for dbrx); stochastic error stays
    below the int8-DCN compression bound already accepted for the
    lane_int8 strategy.
    """
    if accum_dtype is None:
        accum_dtype = _accum_dtype(run)

    def lf(p, tok, lab, ex):
        return loss_fn(p, cfg, tok, lab, extra_embeds=ex, remat=run.remat)

    def step(params, opt_state, tokens, labels, extra=None):
        mb = max(run.microbatch, 1)
        if mb == 1:
            loss, grads = jax.value_and_grad(lf)(params, tokens, labels, extra)
        else:
            B = tokens.shape[0]
            if B % mb:
                raise ValueError(
                    f"per-chip batch {B} must divide by microbatch {mb}")

            def sh(a):
                if a is None:
                    return None
                a = a.reshape(mb, B // mb, *a.shape[1:])
                if batch_axes:
                    # the (B,)→(mb, B/mb) reshape is ambiguous to GSPMD's
                    # propagation; without this constraint the per-µstep
                    # slice keeps the FULL local batch (verified: 16×
                    # activation memory on llama3.2 train_4k)
                    a = jax.lax.with_sharding_constraint(
                        a, P(None, batch_axes, *([None] * (a.ndim - 2))))
                return a

            tokens_mb, labels_mb = sh(tokens), sh(labels)
            extra_mb = sh(extra)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def acc(carry, xs):
                lsum, g = carry
                tok, lab = xs[0], xs[1]
                ex = xs[2] if len(xs) == 3 else None
                l, gi = jax.value_and_grad(lf)(params, tok, lab, ex)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g, gi)
                return (lsum + l, g), None

            xs = ((tokens_mb, labels_mb) if extra is None
                  else (tokens_mb, labels_mb, extra_mb))
            (lsum, gsum), _ = jax.lax.scan(acc, (0.0, g0), xs)
            loss = lsum / mb
            grads = jax.tree.map(lambda g: (g / mb), gsum)
        new_params, new_opt = adamw_update(opt, grads, opt_state, params)
        return loss, new_params, new_opt

    return step


# ---------------------------------------------------------------------------
# lane-decomposed train step (the paper's technique, swappable)
# ---------------------------------------------------------------------------
#
# Per-strategy step CONSTRUCTION dispatches through the repro.comm
# registry too: each flavor is one @register_impl("train_step", ...)
# below, so a new gradsync variant is a registration here plus its
# grad_sync impl in repro/comm/impls.py — never an if-chain edit.  The
# builder contract: fn(comm: LaneComm, ctx: StepContext) -> step where
# step(params, opt_state, tokens, labels, extra=None) -> (loss, params,
# opt_state), traced inside shard_map with ctx.ba manual.

@dataclasses.dataclass(frozen=True)
class StepContext:
    """Everything a registered train-step builder needs besides the comm."""
    cfg: ModelConfig
    run: RunConfig
    opt: AdamWConfig
    mesh: object
    ba: tuple
    single: bool                   # one batch axis: no distinct lane level


def build_train_step_lane(cfg: ModelConfig, run: RunConfig, opt: AdamWConfig,
                          mesh, param_specs, *, tuner=None):
    """Manual over batch axes; collectives via repro.comm.LaneComm.

    The step flavor is resolved from the train_step registry by
    ``run.gradsync`` (valid names: ``repro.comm.strategies_for
    ("train_step")`` — native/lane/lane_pipelined/lane_int8/auto share
    the replicated-parameter step, lane_zero1/lane_zero3 build the
    sharded-optimizer steps; see the registrations below).  All lane
    strategies bucket the flat gradient vector (K = run.gradsync_buckets
    via CommConfig.from_run, 0 = cost-model auto) so the DCN lane hop of
    one bucket overlaps the ICI node collective of the next (§5
    pipeline); ``"auto"`` lets the cost model pick the sync strategy per
    payload and records the choice on the returned comm's ``selections``.
    On a single-batch-axis mesh the node level is trivial and every
    replicated flavor degrades to the native one-shot psum.
    ``param_specs`` is accepted for call-site compatibility but unused:
    the caller owns the shard_map in/out specs of the returned step.
    ``tuner`` (a ``repro.tuning.Tuner`` or None) lands on the comm's
    ``CommConfig.tuner``: measured timing-cache costs then outrank the
    closed-form model in every auto dispatch this step makes.

    Returns ``(step, comm)``: the comm carries the topology
    (``comm.topo``), the recorded auto ``Selection``s, and the
    ``param_layout`` answer the driver keys its master state / shard
    specs / checkpoint layout off (see ``init_lane_train_state``).
    """
    ba = batch_axes(mesh)
    single = len(ba) == 1
    # single-axis meshes get an empty node level (n = 1): the lane axis
    # IS the communicator, matching the paper's N-node/1-per-node corner
    topo = LaneTopology(node_axes=ba[1:], lane_axis=ba[0])
    ccfg = CommConfig.from_run(run)
    if tuner is not None:
        ccfg = dataclasses.replace(ccfg, tuner=tuner)
    comm = LaneComm(topo, ccfg, mesh=mesh)
    ctx = StepContext(cfg, run, opt, mesh, ba, single)
    builder = get_impl("train_step", run.gradsync)
    return builder.fn(comm, ctx), comm


def _parallel_kwargs(ctx: StepContext, comm: LaneComm) -> dict:
    """The static :func:`repro.models.parallel.parallel_context` kwargs of
    this run's third-axis configuration (empty dict = no TP and no EP —
    the zero-overhead default path).

    TP rides a DEGENERATE n=1 decomposition over the mesh's "model" axis
    (the lane axis IS the whole communicator) so the activation
    allgathers resolve through the same (collective, strategy) cells —
    and the same tuner — as every other lowering; EP routes through the
    BATCH-axes communicator ``comm`` itself (every chip is an expert
    owner), so the ``moe_route`` alltoalls share its auto/tuned config.
    """
    run = ctx.run
    pc: dict = {}
    tp = run.model_parallel
    if tp > 1:
        sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
        if sizes.get("model", 1) != tp:
            raise ValueError(
                f"model_parallel={tp} needs a mesh 'model' axis of that "
                f"size (mesh axes: {sizes})")
        tp_comm = LaneComm(LaneTopology(node_axes=(), lane_axis="model"),
                           comm.cfg, mesh=ctx.mesh)
        # expose the model-axis comm for selection introspection (the
        # driver reports comm.selections; TP records on its own comm)
        comm.tp_comm = tp_comm
        pc.update(tp=tp, tp_comm=tp_comm)
    if run.expert_parallel:
        E = ctx.cfg.num_experts
        psz = 1
        for a in ctx.ba:
            psz *= dict(zip(ctx.mesh.axis_names,
                            ctx.mesh.devices.shape))[a]
        if E % max(psz, 1):
            raise ValueError(
                f"expert_parallel needs num_experts={E} divisible by the "
                f"batch-axes chip count p={psz}")
        pc.update(ep=True, ep_comm=comm, ep_blocks=run.ep_blocks)
    return pc


def _make_loss(ctx: StepContext, comm: Optional[LaneComm] = None):
    """The traced loss closure; with a comm and an active third axis it
    enters the :func:`parallel_context` around the forward trace (the
    backward operates on the traced jaxpr, so trace-time routing is all
    the context must cover).  A ``p["ep_experts"]`` entry — the zero3
    step's differentiated local expert tree — is popped off the params
    and carried on the context for the scan body to slice per layer."""
    pc = _parallel_kwargs(ctx, comm) if comm is not None else {}

    def lf(p, tok, lab, ex):
        if not pc:
            return loss_fn(p, ctx.cfg, tok, lab, extra_embeds=ex,
                           remat=ctx.run.remat)
        p = dict(p)
        experts = p.pop("ep_experts", None)
        with parallel_context(**pc, ep_experts=experts):
            return loss_fn(p, ctx.cfg, tok, lab, extra_embeds=ex,
                           remat=ctx.run.remat)
    return lf


# which leaves the tensor-parallel MLP partitions: exactly the weights
# models/layers.mlp_tp computes as zero-padded column blocks per model
# rank (everything else stays bitwise replicated over "model" thanks to
# its custom VJP gathering the input cotangent full)
_TP_LEAF_KEYS = ("w_up", "w_gate", "w_down")


def _is_tp_leaf(keys) -> bool:
    return "mlp" in keys and bool(keys) and keys[-1] in _TP_LEAF_KEYS


def _tp_assemble_tree(grads):
    """Assemble the TP MLP weight grads over the "model" axis.

    Each model rank's grad is the zero-padded column block of exactly its
    slice of the replicated gradient (mlp_tp's custom VJP), so ONE psum
    concatenates disjoint blocks — adding zeros is exact, which is what
    keeps the TP==replicated step pin bitwise.  Non-MLP leaves are
    already bitwise replicated over "model" and pass through untouched.
    """
    import jax.tree_util as jtu

    def fix(path, g):
        keys = [k.key for k in path if isinstance(k, jtu.DictKey)]
        return jax.lax.psum(g, "model") if _is_tp_leaf(keys) else g
    return jtu.tree_map_with_path(fix, grads)


def _tp_row_mask(stack_t, lay) -> jnp.ndarray:
    """Per-element 0/1 fp32 mask over one UNPADDED flat stack row: 1
    exactly on the TP-partitioned MLP weight elements.  Leaf order
    matches :class:`StackLayout` (both use the default tree flatten)."""
    import jax.tree_util as jtu
    flat, _ = jtu.tree_flatten_with_path(stack_t)
    if len(flat) != len(lay.metas):
        raise ValueError(
            f"stack template has {len(flat)} leaves but the layout "
            f"records {len(lay.metas)} — layout drift")
    parts = []
    for (path, _), (shape, _) in zip(flat, lay.metas):
        keys = [k.key for k in path if isinstance(k, jtu.DictKey)]
        parts.append(jnp.full((math.prod(shape),),
                              1.0 if _is_tp_leaf(keys) else 0.0,
                              jnp.float32))
    return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)


# the (L, E, ...) expert FFN weights the expert-parallel zero3 master
# keeps OUT of the gathered flat stack; the router stays in the stack
# (its grad is dense over tokens, and every chip routes locally)
_EXPERT_KEYS = ("w_up", "w_gate", "w_down")


def split_expert_stack(stack: dict):
    """Split a MoE layer stack into (stack_without_experts, experts).

    ``experts`` holds the moe FFN weight leaves in their NATURAL
    (L, E, ...) shapes — the expert-parallel master shards them over E
    across the batch-axes chips (global-rank order) and never gathers
    them; the returned stack keeps the router (and everything else) for
    the ordinary flat 1/p layout.
    """
    if "moe" not in stack:
        raise ValueError(
            f"expert_parallel needs a 'moe' stack entry (stack keys: "
            f"{sorted(stack)})")
    moe = stack["moe"]
    experts = {k: moe[k] for k in _EXPERT_KEYS if k in moe}
    if not experts:
        raise ValueError("'moe' stack entry has no expert FFN weights")
    rest = {k: v for k, v in moe.items() if k not in experts}
    return {**stack, "moe": rest}, experts


def _register_replicated(strategy: str):
    register_param_layout(strategy, "replicated")

    @register_impl("train_step", strategy, auto_ok=False)
    def _build(comm, ctx, _strategy=strategy):
        """Replicated-parameter step: full grad sync + tree AdamW."""
        lf = _make_loss(ctx, comm)
        eff = "native" if ctx.single else _strategy
        tp_on = ctx.run.model_parallel > 1
        vg = _microbatched(
            lambda p, t, l, e: jax.value_and_grad(lf)(p, t, l, e),
            ctx.run.microbatch, _accum_dtype(ctx.run))

        def step(params, opt_state, tokens, labels, extra=None):
            loss, grads = vg(params, tokens, labels, extra)
            loss = jax.lax.pmean(loss, ctx.ba)
            if tp_on:
                grads = _tp_assemble_tree(grads)
            grads = comm.grad_sync(grads, strategy=eff)
            new_params, new_opt = adamw_update(ctx.opt, grads, opt_state,
                                               params)
            return loss, new_params, new_opt
        return step
    return _build


for _s in ("native", "lane", "lane_pipelined", "lane_int8", "auto"):
    _register_replicated(_s)


register_param_layout("lane_quorum", "replicated")


@register_impl("train_step", "lane_quorum", auto_ok=False)
def _build_quorum(comm, ctx: StepContext):
    """Quorum-degraded replicated step: the DEGRADED rung of the ladder.

    Same replicated-parameter step as ``lane``, but it takes a trailing
    ``quorum_mask`` argument — the watchdog's 0/1 float32 vector over
    the lane (pod) axis, replicated (P() spec) so each pod dynamically
    indexes its own bit — and routes gradients through the
    ``lane_quorum`` grad-sync: masked pods contribute zero and the mean
    rescales by the live count (runtime.straggler.quorum_stage).  The
    logged loss degrades the same way (node pmean, then quorum_mean
    over the lane).  With ``quorum_mask=None`` (or all ones) the step
    is the full-quorum path, bit-identical to ``lane`` on power-of-two
    pod counts.  The driver keys the 6-argument shard_map signature off
    ``step.needs_quorum_mask``.
    """
    from repro.runtime.straggler import quorum_mean
    lf = _make_loss(ctx)
    topo = comm.topo
    vg = _microbatched(
        lambda p, t, l, e: jax.value_and_grad(lf)(p, t, l, e),
        ctx.run.microbatch, _accum_dtype(ctx.run))

    def step(params, opt_state, tokens, labels, extra=None,
             quorum_mask=None):
        loss, grads = vg(params, tokens, labels, extra)
        if quorum_mask is None:
            c = jnp.ones((), jnp.float32)
            loss = jax.lax.pmean(loss, ctx.ba)
        else:
            c = jnp.asarray(quorum_mask,
                            jnp.float32)[topo.lane_rank()]
            if topo.node_axes:
                loss = jax.lax.pmean(loss, topo.node_axes)
            loss = quorum_mean(loss, topo.lane_axis, c)
        grads = comm.grad_sync(grads, strategy="lane_quorum",
                               contributing=c)
        new_params, new_opt = adamw_update(ctx.opt, grads, opt_state,
                                           params)
        return loss, new_params, new_opt
    step.needs_quorum_mask = True
    return step


register_param_layout("lane_zero1", "zero1")


@register_impl("train_step", "lane_zero1", auto_ok=False)
def _build_zero1(comm, ctx: StepContext):
    """ZeRO-1 step: data-sharded flat grads + moments through the
    optimizer; the paper's trailing AllGather moves PAST the update
    (same bytes, applied to fresh params, moments stay sharded).  The
    shard layout is bucket-major, so param sharding/unsharding goes
    through gradsync.zero1_param_shard / zero1_unshard with the same K.
    Optimizer semantics match the unsharded adamw_update exactly: the
    TRUE global grad norm is one extra scalar psum over the shard norms
    and weight decay follows the per-element matrices-only mask."""
    if ctx.single:
        return get_impl("train_step", "native").fn(comm, ctx)
    lf = _make_loss(ctx, comm)
    topo, opt, run = comm.topo, ctx.opt, ctx.run
    vg = _microbatched(
        lambda p, t, l, e: jax.value_and_grad(lf)(p, t, l, e),
        run.microbatch, _accum_dtype(run))

    def step(params, opt_state, tokens, labels, extra=None):
        loss, grads = vg(params, tokens, labels, extra)
        loss = jax.lax.pmean(loss, ctx.ba)
        total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
        K = resolve_num_buckets(total, topo.n(), run.gradsync_buckets)
        shard_flat, spec = comm.grad_sync(grads, strategy="lane_zero1",
                                          num_buckets=K)
        pflat, pspec = _flatten_bucket(params, pad_to=K * topo.n())
        mine = zero1_param_shard(pflat, topo, K)
        dmask = zero1_param_shard(
            decay_mask_flat(params, pad_to=K * topo.n()), topo, K)
        # true global grad norm: shards are disjoint over the node level
        # and lane-replicated, so ONE scalar psum over the node axes sums
        # the per-shard square norms to the full-tree norm (padding
        # contributes zeros)
        gnorm = jnp.sqrt(jax.lax.psum(jnp.sum(jnp.square(shard_flat)),
                                      topo.node_axes))
        scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
        # sharded moments: opt_state here is the *sharded* flat state
        newp_shard, new_opt = _adamw_flat(opt, shard_flat, opt_state, mine,
                                          scale=scale, decay_mask=dmask)
        full = zero1_unshard(newp_shard, topo, K)
        new_params = _unflatten_bucket(full, pspec)
        return loss, new_params, new_opt
    return step


register_param_layout("lane_zero3", "zero3")


@register_impl("train_step", "lane_zero3", auto_ok=False)
def _build_zero3(comm, ctx: StepContext):
    """ZeRO-3/FSDP step, family-agnostic: the family's registered
    BlockSpec (models/blockstack.py) splits the params into the scanned
    layer stack, the "extras" pseudo-layer (embed/final_norm/...) and the
    replicated leftovers (the hybrid shared attention block only).  The
    stack stays sharded 1/p per chip (shard_stack layout) and is
    re-gathered LAYER BY LAYER inside the forward scan via
    comm.prefetch_allgather — the pipelined AG(lane)→AG(node) with a
    one-layer prefetch buffer so layer i+1's gather overlaps layer i's
    compute (run.fsdp_prefetch: 0 = cost-model block count, >0 =
    override, -1 = blocking negative control); the extras shard gathers
    ONCE per step through the same pipeline.  run.fsdp_regather=True
    re-runs each layer's gather in the backward under remat so backward
    residuals stay 1/p too (see ShardedStack).  Gradients for both
    sharded trees need no separate sync: the gathers' AD transposes ARE
    the lane_zero3 reduce-scatters; only the replicated leftovers (when
    any) sync through the bucketed lane path.  Optimizer semantics match
    native: one scalar psum over the (lane × node) shard norms recovers
    the true global grad norm for clipping, and the flat decay masks
    reproduce matrices-only weight decay."""
    ba, run, opt, cfg = ctx.ba, ctx.run, ctx.opt, ctx.cfg
    if len(ba) < 2:
        # zero3 shards over the (lane × node) product and its gather
        # pipeline needs the two levels to be DISTINCT axes; there is no
        # sensible single-axis degradation (unlike the other strategies,
        # which fall back to native)
        raise ValueError(
            "lane_zero3 needs distinct lane and node batch axes (a "
            "multi-pod mesh); use native or lane_zero1 on single-"
            f"batch-axis meshes (got batch axes {ba})")
    topo = comm.topo
    lf = _make_loss(ctx, comm)
    ep_on = run.expert_parallel
    tp_on = run.model_parallel > 1
    n_, N_ = topo.sizes(ctx.mesh)
    p_ = max(n_ * N_, 1)
    layouts = zero3_stack_layouts(cfg, ep=ep_on)
    lay_b, lay_e = layouts["blocks"], layouts["extras"]
    # abstract stack template (same leaf order as lay_b): the TP row mask
    # and the EP expert-dtype template both key off it
    fspec3 = block_stack_spec(cfg)
    stack_t, _, _ = split_params(fspec3, _abs_params(cfg))
    exp_t = None
    if ep_on:
        stack_t, exp_t = split_expert_stack(stack_t)
    mask_row = _tp_row_mask(stack_t, lay_b) if tp_on else None
    Bb = resolve_prefetch_blocks(lay_b.row_elems, n_, N_, run.fsdp_prefetch)
    # extras (vocab·d embed + head) resolves from its OWN row payload —
    # a positive override tuned for the layer stack is not inherited
    Be = resolve_extras_prefetch_blocks(lay_e.row_elems, n_, N_,
                                        run.fsdp_prefetch)
    blocking = run.fsdp_prefetch == -1
    if blocking and run.fsdp_regather:
        raise ValueError(
            "fsdp_prefetch=-1 (the blocking negative control) and "
            "fsdp_regather are mutually exclusive: the re-gather scan "
            "would silently replace the blocking lowering the control "
            "is supposed to measure")

    def gather_layer(x):
        return lay_b.unflatten_row(comm.prefetch_allgather(x, num_blocks=Bb))

    def gather_extras(x):
        return lay_e.unflatten_row(comm.prefetch_allgather(x, num_blocks=Be))

    def step(params, opt_state, tokens, labels, extra=None):
        """lane_zero3 train step.

        params["blocks"] / params["extras"] are this chip's shards — any
        shape reshapeable to (L, B·s) / (B·s,), e.g. the local blocks of
        the host-side (L, B, n·N, s) layouts from shard_stack; every
        other entry is replicated (the family spec's replicated_keys).
        opt_state is the split {"rest", "blocks", "extras"} state of
        zero3_opt_init.  The returned params keep both shards SHARDED
        (same shapes as the input): ZeRO-3 never materializes full layer
        parameters outside the per-layer prefetch window (the extras
        pseudo-layer stays live for the step — the "+1 layer" of the
        memory model).
        """
        bshape = params["blocks"].shape
        eshape = params["extras"].shape
        shards_b = params["blocks"].reshape(lay_b.length, -1)
        shards_e = params["extras"].reshape(-1)
        experts = params["experts"] if ep_on else {}
        repl = {k: v for k, v in params.items()
                if k not in ("blocks", "extras", "experts")}
        have_repl = bool(jax.tree.leaves(repl))

        # the extras pseudo-layer gathers ONCE per step, OUTSIDE the
        # microbatch scan (with microbatching the naive in-loss gather
        # would re-gather the vocab·d payload per µbatch); the explicit
        # vjp keeps the AD transpose — applying it to the accumulated
        # cotangent below IS the extras reduce-scatter
        extras_tree, extras_vjp = jax.vjp(gather_extras, shards_e)

        def vg(repl_p, sh_b, ext, exp, tok, lab, ex):
            def lf3(repl_p, sh_b, ext, exp):
                p = dict(repl_p)
                p.update(ext)
                p["blocks"] = ShardedStack(sh_b, gather_layer,
                                           prefetch=not blocking,
                                           regather=run.fsdp_regather)
                if ep_on:
                    # fp32 master -> model dtype inside the trace, the
                    # same cast point as the gather path's unflatten_row
                    p["ep_experts"] = jax.tree.map(
                        lambda a, t: a.astype(t.dtype), exp, exp_t)
                return lf(p, tok, lab, ex)
            return jax.value_and_grad(lf3, argnums=(0, 1, 2, 3))(
                repl_p, sh_b, ext, exp)

        vg = _microbatched(vg, run.microbatch, _accum_dtype(run))
        loss, (g_repl, g_b, g_ext, g_exp) = vg(repl, shards_b, extras_tree,
                                               experts, tokens, labels,
                                               extra)
        (g_e,) = extras_vjp(jax.tree.map(
            lambda g, t: g.astype(t.dtype), g_ext, extras_tree))
        loss = jax.lax.pmean(loss, ba)
        # the gathers' transposes already reduce-scattered g_b/g_e over
        # (lane × node) — sum over replicas; only the mean is left.  The
        # EP expert grads arrive COMPLETE on the owner the same way (the
        # routing alltoall's transpose returns every chip's cotangent to
        # the expert's home), so they too only need the replica mean
        nrep = _axprod(ba)
        g_b, g_e = g_b / nrep, g_e / nrep
        if ep_on:
            g_exp = jax.tree.map(lambda g: g / nrep, g_exp)
        if tp_on:
            # each model rank's flat stripe holds the zero-padded column
            # block of the TP-partitioned MLP leaves (mlp_tp's custom
            # VJP); one masked psum over "model" assembles them exactly
            # (adding zeros is bit-exact) and leaves every other element
            # — already bitwise replicated over "model" — untouched
            row = mask_row
            pad = shards_b.shape[1] * p_ - row.shape[0]
            if pad:
                row = jnp.concatenate(
                    [row, jnp.zeros((pad,), jnp.float32)])
            m = jnp.tile(zero3_param_shard(row, topo, Bb), lay_b.length)
            gb = g_b.reshape(-1)
            g_b = (gb * (1 - m)
                   + jax.lax.psum(gb * m, "model")).reshape(g_b.shape)
            if have_repl:
                g_repl = _tp_assemble_tree(g_repl)
        if have_repl:
            g_repl = comm.grad_sync(g_repl, strategy="lane")
        # true global grad norm over stack + extras + experts +
        # leftovers: the 1/p stripes (and the E/p expert slices) are
        # disjoint, so one scalar psum over BOTH levels totals their
        # square norms; g_repl is fully reduced (replicated), added once
        loc_sq = jnp.sum(jnp.square(g_b)) + jnp.sum(jnp.square(g_e))
        if ep_on:
            loc_sq = loc_sq + sum(
                jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g_exp))
        gsq = jax.lax.psum(loc_sq, (topo.lane_axis, *topo.node_axes))
        if have_repl:
            gsq = gsq + global_norm(g_repl) ** 2
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
        new_repl, new_opt_rest = adamw_update(
            opt, g_repl, opt_state["rest"], repl, grad_norm=gnorm)
        dmask_b = jnp.tile(
            zero3_param_shard(lay_b.decay_mask(shards_b.shape[1] * p_),
                              topo, Bb),
            lay_b.length)
        ob = opt_state["blocks"]
        newp_b, nob = _adamw_flat(
            opt, g_b.reshape(-1),
            {"m": ob["m"].reshape(-1), "v": ob["v"].reshape(-1),
             "count": ob["count"]},
            shards_b.reshape(-1), scale=scale, decay_mask=dmask_b)
        dmask_e = zero3_param_shard(
            lay_e.decay_mask(shards_e.shape[0] * p_), topo, Be)
        oe = opt_state["extras"]
        newp_e, noe = _adamw_flat(
            opt, g_e, {"m": oe["m"].reshape(-1), "v": oe["v"].reshape(-1),
                       "count": oe["count"]},
            shards_e, scale=scale, decay_mask=dmask_e)
        new_params = dict(new_repl)
        new_params["blocks"] = newp_b.reshape(bshape)
        new_params["extras"] = newp_e.reshape(eshape)
        new_opt = {"rest": new_opt_rest,
                   "blocks": {"m": nob["m"].reshape(ob["m"].shape),
                              "v": nob["v"].reshape(ob["v"].shape),
                              "count": nob["count"]},
                   "extras": {"m": noe["m"].reshape(oe["m"].shape),
                              "v": noe["v"].reshape(oe["v"].shape),
                              "count": noe["count"]}}
        if ep_on:
            # the (L, E/p, ...) local expert master updates in place —
            # same elementwise AdamW math as the flat shards, natural
            # shapes (every FFN leaf decays: ndim >= 2, matching the
            # gather layout's per-element decay mask)
            new_exp, new_opt_exp = adamw_update(
                opt, g_exp, opt_state["experts"], experts,
                grad_norm=gnorm)
            new_params["experts"] = new_exp
            new_opt["experts"] = new_opt_exp
        return loss, new_params, new_opt
    return step


def _axprod(axes):
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def _accum_dtype(run: RunConfig):
    return jnp.bfloat16 if run.accum_dtype == "bfloat16" else jnp.float32


def _microbatched(vg_fn, mb: int, accum_dtype):
    """Microbatch gradient accumulation for the lane step builders.

    Wraps a value-and-grad callable ``vg(*diff_args, tokens, labels,
    extra) -> (loss, grads)`` (``grads`` mirroring the differentiated
    args) into a version with the identical signature that splits the
    LOCAL batch (this is inside shard_map — the leading dim is already
    the per-chip shard) into ``mb`` µbatches scanned sequentially.
    Gradients accumulate in ``accum_dtype``: fp32 is parity-exact with
    the unaccumulated step up to summation order; bf16 halves the
    accumulator's HBM residency (the same error class already accepted
    for the lane_int8 DCN hop).  ``mb <= 1`` returns ``vg_fn`` unchanged
    — zero overhead on the default path.
    """
    if mb <= 1:
        return vg_fn

    def wrapped(*args):
        *diff, tokens, labels, extra = args
        B = tokens.shape[0]
        if B % mb:
            raise ValueError(
                f"local batch {B} not divisible by microbatch={mb} "
                f"(pick a global batch divisible by devices × microbatch)")
        sh = lambda a: None if a is None else \
            a.reshape(mb, B // mb, *a.shape[1:])
        toks, labs, ex = sh(tokens), sh(labels), sh(extra)
        # grads structure comes from the wrapped fn itself (a single tree
        # or a tuple, depending on argnums) — eval_shape, never traced in
        _, g_shape = jax.eval_shape(
            vg_fn, *diff, toks[0], labs[0], None if ex is None else ex[0])
        g0 = jax.tree.map(lambda s: jnp.zeros(s.shape, accum_dtype),
                          g_shape)

        def acc(carry, xs):
            lsum, g = carry
            t, l = xs[0], xs[1]
            e = xs[2] if len(xs) == 3 else None
            li, gi = vg_fn(*diff, t, l, e)
            g = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), g, gi)
            return (lsum + li, g), None

        xs = (toks, labs) if ex is None else (toks, labs, ex)
        (lsum, gsum), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), g0),
                                       xs)
        return lsum / mb, jax.tree.map(lambda g: g / mb, gsum)
    return wrapped


def _adamw_flat(opt: AdamWConfig, g, state, p, *, scale=None,
                decay_mask=None):
    """AdamW on a flat fp32 shard (ZeRO-1 / ZeRO-3).

    scale: global-norm clip factor — the CALLER computes it from the true
    global norm (one extra scalar psum over shard norms) so every shard
    clips by the same full-model scale, exactly like adamw_update; None
    skips clipping.  decay_mask: 0/1 per-element mask of the leaves
    adamw_update would decay (matrices; see gradsync.decay_mask_flat);
    None decays every element uniformly (legacy behavior, kept for bare
    callers)."""
    from repro.optim.adamw import cosine_lr
    count = state["count"] + 1
    lr = cosine_lr(opt, count)
    if scale is not None:
        g = g * scale
    m = opt.b1 * state["m"] + (1 - opt.b1) * g
    v = opt.b2 * state["v"] + (1 - opt.b2) * jnp.square(g)
    c1 = 1 - opt.b1 ** count.astype(jnp.float32)
    c2 = 1 - opt.b2 ** count.astype(jnp.float32)
    decay = opt.weight_decay * p
    if decay_mask is not None:
        decay = decay * decay_mask
    step = (m / c1) / (jnp.sqrt(v / c2) + opt.eps) + decay
    return p - lr * step, {"m": m, "v": v, "count": count}


def zero1_opt_init(params, topo_n: int, num_buckets: int = 0):
    """Flat sharded fp32 optimizer state for the lane_zero1 path.

    Pass ``run.gradsync_buckets`` as num_buckets: the shard size depends
    on the bucketed padding (K·n), so this MUST match the train step's
    override — resolve_num_buckets is deterministic, so the default 0
    (auto) agrees with the step's auto choice, but a nonzero override on
    one side only produces a shape mismatch inside the jitted step.
    """
    total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
    K = resolve_num_buckets(total, topo_n, num_buckets)
    padded = -(-total // (K * topo_n)) * (K * topo_n)
    sz = padded // topo_n
    return {"m": jnp.zeros((sz,), jnp.float32),
            "v": jnp.zeros((sz,), jnp.float32),
            "count": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# ZeRO-3 stack sharding (the lane_zero3 / FSDP path)
# ---------------------------------------------------------------------------
#
# The family's scanned layer stack (every leaf (L, ...)) is flattened per
# layer into an (L, D) fp32 master copy, padded to D_pad = B·n·N·s, and
# each chip keeps the (L, B·s) stripe of the gradsync.zero3_param_shard
# layout; the non-stack, non-replicated params (embed/final_norm/...)
# become the "extras" pseudo-layer — one more (1, Be, n·N, se) master.
# The host-side arrays are shaped (L, B, n·N, s) so a plain NamedSharding
# P(None, None, (*node_axes, lane_axis), None) places exactly stripe
# (node_rank·N + lane_rank) on each chip — no host-side rank arithmetic.
# The layout machinery itself is family-agnostic and lives in
# repro.models.blockstack (StackLayout / shard_stack /
# resolve_prefetch_blocks, re-exported here); everything both sides of
# the shard_map boundary must agree on derives deterministically from
# the ModelConfig via zero3_stack_layouts.

def zero3_stack_layouts(cfg: ModelConfig, ep: bool = False) -> dict:
    """``{"blocks": StackLayout, "extras": StackLayout}`` of the family's
    sharded stacks (derived via eval_shape — never materializes
    weights).  ``blocks`` is the (L, ...) scanned stack; ``extras`` is
    the single pseudo-layer of everything else except the family spec's
    replicated keys.  ``ep=True`` (expert parallelism) keeps the MoE
    expert FFN leaves OUT of the blocks layout — they live in the
    never-gathered (L, E/p, ...) local expert master instead."""
    fspec = block_stack_spec(cfg)
    abs_params = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))
    stack, extras, _ = split_params(fspec, abs_params)
    if ep:
        stack, _ = split_expert_stack(stack)
    return {"blocks": stack_layout(stack, stacked=True),
            "extras": stack_layout(extras, stacked=False)}


def zero3_opt_init(cfg: ModelConfig, params, n: int, N: int,
                   fsdp_prefetch: int = 0, ep: bool = False):
    """Split optimizer state for the lane_zero3 step: flat sharded fp32
    moments in the (L, B, p, s) master layouts for the layer stack AND
    the extras pseudo-layer, ordinary AdamW tree state for the family's
    replicated keys (empty for most families; the hybrid weight-shared
    attention block).  The B resolution MUST match the step's
    (resolve_prefetch_blocks is deterministic, so the default 0 agrees;
    pass the same run.fsdp_prefetch override on both sides).  ``ep=True``
    adds the "experts" entry: natural-shape fp32 moments for the expert
    master (host-side FULL (L, E, ...) — the driver's NamedSharding
    places the E/p slice per chip exactly like the params master)."""
    fspec = block_stack_spec(cfg)
    stack, extras, repl = split_params(fspec, params)
    experts = None
    if ep:
        stack, experts = split_expert_stack(stack)
    # derive the moment shapes FROM shard_stack (via eval_shape, no
    # weight materialization) so the layout invariant lives in one place
    sh_b = jax.eval_shape(
        lambda b: shard_stack(b, n, N, fsdp_prefetch)[0], stack)
    sh_e = jax.eval_shape(
        lambda e: shard_stack(e, n, N, fsdp_prefetch, stacked=False)[0],
        extras)
    flat_state = lambda s: {"m": jnp.zeros(s.shape, jnp.float32),
                            "v": jnp.zeros(s.shape, jnp.float32),
                            "count": jnp.zeros((), jnp.int32)}
    out = {"rest": adamw_init(repl), "blocks": flat_state(sh_b),
           "extras": flat_state(sh_e)}
    if ep:
        out["experts"] = adamw_init(experts)
    return out


# ---------------------------------------------------------------------------
# driver-side master state: layout-aware init + shard specs + ckpt layout
# ---------------------------------------------------------------------------
#
# Everything the training driver must agree on with the jitted step —
# which master layout the params/optimizer state live in, the shard_map
# in/out PartitionSpecs of that layout, and the checkpoint layout that
# canonicalizes it — is derived HERE from the same LaneComm.param_layout
# answer the step builders register, so a new strategy's driver wiring is
# its register_param_layout(...) line, not a fourth if-chain.

@dataclasses.dataclass
class LaneTrainState:
    """Host-side master state for one lane train-step flavor.

    params/opt_state: host (global-view) arrays in the step's master
        layout — device_put against ``to_shardings(mesh)`` before use.
    pspecs/ospecs: the matching shard_map in/out PartitionSpec trees.
    ckpt_layout: the repro.checkpoint layout that canonicalizes this
        state on disk (thread into AsyncCheckpointer/restore_checkpoint).
    """
    params: object
    opt_state: object
    pspecs: object
    ospecs: object
    ckpt_layout: object

    def to_shardings(self, mesh):
        from jax.sharding import NamedSharding
        mk = lambda specs: jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return mk(self.pspecs), mk(self.ospecs)


def zero1_checkpoint_layout(params, n: int, num_buckets: int = 0):
    """Checkpoint layout of the lane_zero1 flat optimizer moments (the
    SAME K/padding resolution as zero1_opt_init and the train step)."""
    from repro.checkpoint import Zero1CheckpointLayout
    total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
    K = resolve_num_buckets(total, n, num_buckets)
    return Zero1CheckpointLayout(total, K, n)


def zero3_checkpoint_layout(cfg: ModelConfig, n: int, N: int,
                            fsdp_prefetch: int = 0, ep: bool = False):
    """Checkpoint layout of the lane_zero3 (L, B, p, s) masters — the
    layer stack AND the extras pseudo-layer (the SAME B resolution as
    shard_stack / zero3_opt_init / the step).  ``ep=True`` records the
    expert-parallel flavor: the blocks geometry excludes the expert FFN
    leaves (they checkpoint in their natural (L, E, ...) shapes, which
    ARE canonical — identity passthrough)."""
    from repro.checkpoint import Zero3CheckpointLayout
    layouts = zero3_stack_layouts(cfg, ep=ep)
    lay_b, lay_e = layouts["blocks"], layouts["extras"]
    Bb = resolve_prefetch_blocks(lay_b.row_elems, n, N, fsdp_prefetch)
    Be = resolve_extras_prefetch_blocks(lay_e.row_elems, n, N,
                                        fsdp_prefetch)
    return Zero3CheckpointLayout(lay_b.length, lay_b.row_elems, Bb,
                                 max(n * N, 1),
                                 extra_elems=lay_e.row_elems,
                                 extra_blocks=Be, ep=ep)


def init_lane_train_state(cfg: ModelConfig, run: RunConfig, mesh,
                          params, comm: LaneComm = None) -> LaneTrainState:
    """Master state + specs + checkpoint layout for ``run.gradsync``.

    ``params`` is the replicated init_model tree; the ZeRO flavors
    re-lay it out host-side (blockstack.shard_stack) and build fresh sharded
    optimizer state.  Pass the ``comm`` returned by
    ``build_train_step_lane`` so the layout/topology decision is read off
    the SAME object the step was built against (None re-derives it from
    the mesh — identical by construction, for callers without a step).
    """
    from repro.checkpoint import REPLICATED
    if comm is None:
        ba = batch_axes(mesh)
        topo = LaneTopology(node_axes=ba[1:], lane_axis=ba[0])
        comm = LaneComm(topo, CommConfig.from_run(run), mesh=mesh)
    topo = comm.topo
    kind = comm.param_layout(run.gradsync)
    n, N = topo.sizes(mesh)
    pspecs = jax.tree.map(lambda _: P(), params)
    if kind == "replicated":
        opt = adamw_init(params)
        return LaneTrainState(params, opt, pspecs,
                              jax.tree.map(lambda _: P(), opt), REPLICATED)
    if kind == "zero1":
        layout = zero1_checkpoint_layout(params, n, run.gradsync_buckets)
        opt = {"m": jnp.zeros((layout.padded,), jnp.float32),
               "v": jnp.zeros((layout.padded,), jnp.float32),
               "count": jnp.zeros((), jnp.int32)}
        ospecs = {"m": P(topo.node_axes), "v": P(topo.node_axes),
                  "count": P()}
        return LaneTrainState(params, opt, pspecs, ospecs, layout)
    if kind != "zero3":
        raise ValueError(f"unknown lane state layout kind {kind!r}")
    ep_on = run.expert_parallel
    fspec = block_stack_spec(cfg)
    stack, extras, repl = split_params(fspec, params)
    experts = None
    if ep_on:
        stack, experts = split_expert_stack(stack)
    shards_b, Bb = shard_stack(stack, n, N, run.fsdp_prefetch)
    shards_e, Be = shard_stack(extras, n, N, run.fsdp_prefetch,
                               stacked=False)
    layout = zero3_checkpoint_layout(cfg, n, N, run.fsdp_prefetch,
                                     ep=ep_on)
    if tuple(shards_b.shape) != layout.master_shape \
            or Bb != layout.num_blocks \
            or tuple(shards_e.shape) != layout.extra_master_shape \
            or Be != layout.extra_blocks:
        # both sides derive B/padding from the stack element counts; if
        # the real trees and zero3_stack_layouts ever disagree the
        # checkpoint would silently record the wrong geometry
        raise ValueError(
            f"zero3 master layout drift: sharded stacks "
            f"{shards_b.shape}/{shards_e.shape} (B={Bb}/{Be}) vs "
            f"checkpoint layout {layout.master_shape}/"
            f"{layout.extra_master_shape} "
            f"(B={layout.num_blocks}/{layout.extra_blocks})")
    p3 = dict(repl)
    p3["blocks"] = shards_b
    p3["extras"] = shards_e
    if ep_on:
        # fp32 expert master in NATURAL (L, E, ...) shapes; the E-dim
        # sharding below places exactly experts [r·E/p, (r+1)·E/p) on
        # global rank r = lane_rank·n + node_rank — the owner order
        # moe_block_ep routes by
        p3["experts"] = jax.tree.map(
            lambda a: jnp.asarray(a, jnp.float32), experts)
    opt = zero3_opt_init(cfg, params, n, N, run.fsdp_prefetch, ep=ep_on)
    master_spec = P(None, None, (*topo.node_axes, topo.lane_axis), None)
    pspecs = jax.tree.map(lambda _: P(), p3)
    pspecs["blocks"] = pspecs["extras"] = master_spec
    ospecs = jax.tree.map(lambda _: P(), opt)
    ospecs["blocks"]["m"] = ospecs["blocks"]["v"] = master_spec
    ospecs["extras"]["m"] = ospecs["extras"]["v"] = master_spec
    if ep_on:
        expert_spec = P(None, (topo.lane_axis, *topo.node_axes))
        exp_specs = jax.tree.map(lambda _: expert_spec, experts)
        pspecs["experts"] = exp_specs
        ospecs["experts"]["m"] = exp_specs
        ospecs["experts"]["v"] = exp_specs
    return LaneTrainState(p3, opt, pspecs, ospecs, layout)


# ---------------------------------------------------------------------------
# cross-layout restore (zero3 <-> zero1 <-> replicated, via canonical order)
# ---------------------------------------------------------------------------
#
# Every checkpoint layout canonicalizes to the SAME underlying element
# order (the unpadded flat parameter order — see the flat-order
# primitives in repro.checkpoint.layouts), so a checkpoint written under
# one strategy layout restores into another: lift the stored canonical
# leaves to the replicated (params, adamw) form, then re-lay them out
# through the destination layout exactly like init_lane_train_state lays
# out a fresh init.  Pure reshape/transpose end to end; the only value
# change is the dtype cast when a fp32 ZeRO master restores into a
# sub-fp32 replicated parameter (and back).  Geometry that genuinely
# differs — a different model — still raises.

def _abs_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


def _abs_adamw(params_t):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"m": jax.tree.map(f32, params_t),
            "v": jax.tree.map(f32, params_t),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def _canonical_state_template(cfg: ModelConfig, entry: dict):
    """Abstract (params, opt_state) tree whose leaves have the CANONICAL
    shapes a checkpoint of layout ``entry`` stores — the pairing target
    for repro.checkpoint.load_canonical's raw arrays."""
    kind = (entry or {}).get("kind", "replicated")
    params_t = _abs_params(cfg)
    if kind == "replicated":
        return params_t, _abs_adamw(params_t)
    f32 = lambda shape: jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
    count_t = jax.ShapeDtypeStruct((), jnp.int32)
    if kind == "zero1":
        total = int(entry.get("total_elems", 0))
        return params_t, {"m": f32((total,)), "v": f32((total,)),
                         "count": count_t}
    if kind != "zero3":
        raise ValueError(f"unknown checkpoint layout kind {kind!r}")
    if not entry.get("extra_elems"):
        raise ValueError(
            "zero3 checkpoint predates the extras pseudo-layer (no "
            "extra_elems in its layout entry); cross-layout restore "
            "needs the current master format")
    fspec = block_stack_spec(cfg)
    stack_t, extras_t, repl_t = split_params(fspec, params_t)
    ep = bool(entry.get("ep"))
    exp_t = None
    if ep:
        stack_t, exp_t = split_expert_stack(stack_t)
    lay_b = stack_layout(stack_t, stacked=True)
    lay_e = stack_layout(extras_t, stacked=False)
    flat_t = lambda lay: {"m": f32((lay.length, lay.row_elems)),
                          "v": f32((lay.length, lay.row_elems)),
                          "count": count_t}
    p_t = dict(repl_t)
    p_t["blocks"] = f32((lay_b.length, lay_b.row_elems))
    p_t["extras"] = f32((1, lay_e.row_elems))
    o_t = {"rest": _abs_adamw(repl_t), "blocks": flat_t(lay_b),
           "extras": flat_t(lay_e)}
    if ep:
        # the expert master checkpoints in its natural (L, E, ...) fp32
        # shapes — natural IS canonical for experts (identity layout)
        exp_f32 = jax.tree.map(lambda l: f32(l.shape), exp_t)
        p_t["experts"] = exp_f32
        o_t["experts"] = {"m": exp_f32, "v": exp_f32, "count": count_t}
    return p_t, o_t


def state_to_replicated(cfg: ModelConfig, entry: dict, state):
    """Canonical-form (params, opt_state) of layout ``entry`` -> the
    replicated (params tree, adamw tree) form.  Host-side plumbing: the
    flat-order split/unstack primitives only."""
    import numpy as np
    kind = (entry or {}).get("kind", "replicated")
    if kind == "replicated":
        return state
    params, opt = state
    params_t = _abs_params(cfg)
    if kind == "zero1":
        from repro.checkpoint import split_flat_order
        leaves_t = jax.tree.leaves(params_t)
        treedef = jax.tree.structure(params_t)
        mk = lambda flat: jax.tree.unflatten(
            treedef, split_flat_order(flat, [l.shape for l in leaves_t]))
        return params, {"m": mk(opt["m"]), "v": mk(opt["v"]),
                        "count": opt["count"]}
    if kind != "zero3":
        raise ValueError(f"unknown lane state layout kind {kind!r}")
    fspec = block_stack_spec(cfg)
    stack_t, extras_t, _ = split_params(fspec, params_t)
    ep = bool(entry.get("ep"))
    exp_t = None
    if ep:
        stack_t, exp_t = split_expert_stack(stack_t)
    lay_b = stack_layout(stack_t, stacked=True)
    lay_e = stack_layout(extras_t, stacked=False)
    p_repl = {k: v for k, v in params.items()
              if k not in ("blocks", "extras", "experts")}
    p_repl.update(lay_e.unflatten(np.asarray(params["extras"])))
    blocks = lay_b.unflatten(np.asarray(params["blocks"]))
    if ep:
        # fold the natural-shape expert master back into the stack's moe
        # subtree (cast to the model's parameter dtype, like unflatten)
        moe = dict(blocks.get("moe", {}))
        for k, v in params["experts"].items():
            moe[k] = np.asarray(v).astype(exp_t[k].dtype)
        blocks = {**blocks, "moe": moe}
    p_repl["blocks"] = blocks

    def moments(name):
        tree = {k: v for k, v in opt["rest"][name].items()}
        tree.update(lay_e.unflatten(np.asarray(opt["extras"][name]),
                                    dtype=np.float32))
        blk = lay_b.unflatten(np.asarray(opt["blocks"][name]),
                              dtype=np.float32)
        if ep:
            moe_m = dict(blk.get("moe", {}))
            for k, v in opt["experts"][name].items():
                moe_m[k] = np.asarray(v)
            blk = {**blk, "moe": moe_m}
        tree["blocks"] = blk
        return tree

    return p_repl, {"m": moments("m"), "v": moments("v"),
                    "count": opt["blocks"]["count"]}


def replicated_to_state(cfg: ModelConfig, run: RunConfig, n: int, N: int,
                        params, opt_state, *, kind: str):
    """Replicated (params, adamw) values -> the host master state of
    layout ``kind`` for the CURRENT (n, N) topology — the value-carrying
    twin of init_lane_train_state's layout path."""
    import numpy as np
    if kind == "replicated":
        # cast back into the model's parameter dtypes (a fp32 ZeRO
        # master restoring into a bf16 replicated run)
        params_t = _abs_params(cfg)
        params = jax.tree.map(
            lambda v, t: np.asarray(v).astype(t.dtype), params, params_t)
        return params, opt_state
    if kind == "zero1":
        import jax.tree_util as jtu
        from repro.checkpoint import concat_flat_order
        layout = zero1_checkpoint_layout(params, n, run.gradsync_buckets)
        lay1 = lambda tree: layout.from_canonical(
            (jtu.DictKey("m"),),
            concat_flat_order(jax.tree.leaves(tree)))
        return params, {"m": lay1(opt_state["m"]),
                        "v": lay1(opt_state["v"]),
                        "count": opt_state["count"]}
    if kind != "zero3":
        raise ValueError(f"unknown lane state layout kind {kind!r}")
    ep = run.expert_parallel
    fspec = block_stack_spec(cfg)
    stack, extras, repl = split_params(fspec, params)
    experts = None
    if ep:
        stack, experts = split_expert_stack(stack)
    shards_b, _ = shard_stack(stack, n, N, run.fsdp_prefetch)
    shards_e, _ = shard_stack(extras, n, N, run.fsdp_prefetch,
                              stacked=False)
    p3 = dict(repl)
    p3["blocks"] = np.asarray(shards_b)
    p3["extras"] = np.asarray(shards_e)
    if ep:
        p3["experts"] = jax.tree.map(
            lambda a: np.asarray(a, np.float32), experts)

    def flat_state(name):
        m_stack, m_extras, _ = split_params(fspec, opt_state[name])
        m_exp = None
        if ep:
            m_stack, m_exp = split_expert_stack(m_stack)
        return (np.asarray(shard_stack(m_stack, n, N,
                                       run.fsdp_prefetch)[0]),
                np.asarray(shard_stack(m_extras, n, N, run.fsdp_prefetch,
                                       stacked=False)[0]),
                m_exp)
    mb, me, mx = flat_state("m")
    vb, ve, vx = flat_state("v")
    count = opt_state["count"]
    _, _, m_repl = split_params(fspec, opt_state["m"])
    _, _, v_repl = split_params(fspec, opt_state["v"])
    o3 = {"rest": {"m": m_repl, "v": v_repl, "count": count},
          "blocks": {"m": mb, "v": vb, "count": count},
          "extras": {"m": me, "v": ve, "count": count}}
    if ep:
        asf32 = lambda t: jax.tree.map(
            lambda a: np.asarray(a, np.float32), t)
        o3["experts"] = {"m": asf32(mx), "v": asf32(vx), "count": count}
    return p3, o3


def restore_lane_train_state(ckpt_dir: str, cfg: ModelConfig,
                             run: RunConfig, mesh, st: LaneTrainState,
                             step: Optional[int] = None, shardings=None):
    """Restore a checkpoint into ``st``'s master layout, converting
    through the canonical replicated form when the checkpoint was
    written under a DIFFERENT strategy layout (e.g. a ``lane_zero3``
    checkpoint into a ``lane_zero1`` or replicated run, and back).
    Same-kind restores delegate to the ordinary layout-validated path.
    Returns ((params, opt_state), step); ``shardings`` (a
    ``st.to_shardings(mesh)`` pair) device_puts the result.

    Integrity: leaves crc-verify as they load.  With ``step=None`` a
    corrupt newest checkpoint falls back to the newest committed step
    that verifies (losing the steps since that commit, never the
    restart); an EXPLICIT step raises ``CheckpointCorruptError``.
    Geometry ValueErrors always propagate — a config mismatch must not
    be "survived" by resurrecting an older checkpoint."""
    import sys
    from repro.checkpoint import CheckpointCorruptError, committed_steps
    candidates = [step] if step is not None \
        else list(reversed(committed_steps(ckpt_dir)))
    if not candidates:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    last_err = None
    for cand in candidates:
        try:
            return _restore_lane_state_at(ckpt_dir, cfg, run, mesh, st,
                                          cand, shardings)
        except CheckpointCorruptError as e:
            last_err = e
            if step is not None:
                raise
            print(f"checkpoint step {cand} is corrupt ({e}); falling "
                  f"back to the previous committed step",
                  file=sys.stderr, flush=True)
    raise CheckpointCorruptError(
        f"no verifiable checkpoint in {ckpt_dir} "
        f"(tried steps {candidates})") from last_err


def _restore_lane_state_at(ckpt_dir: str, cfg: ModelConfig,
                           run: RunConfig, mesh, st: LaneTrainState,
                           step: int, shardings=None):
    from repro.checkpoint import load_canonical, restore_checkpoint
    from repro.checkpoint.store import peek_manifest
    # decide the kind from the manifest ALONE: the common same-kind
    # resume must not pay a second full read of multi-GB master arrays
    man, got = peek_manifest(ckpt_dir, step)
    entry = man.get("layout") or {}
    src_kind = entry.get("kind", "replicated")
    # the ep flag changes the zero3 master GEOMETRY (expert leaves leave
    # the flat stack): a same-kind/different-ep restore must go through
    # the canonical form, not the layout-validated fast path
    same_ep = bool(entry.get("ep", False)) == \
        bool(getattr(st.ckpt_layout, "ep", False))
    if src_kind == st.ckpt_layout.kind and same_ep:
        return restore_checkpoint(
            ckpt_dir, (st.params, st.opt_state), step=got,
            shardings=shardings, layout=st.ckpt_layout)
    _, arrays, got = load_canonical(ckpt_dir, got)
    src_t = _canonical_state_template(cfg, entry)
    refs = jax.tree.leaves(src_t)
    if len(refs) != len(arrays):
        raise ValueError(
            f"checkpoint holds {len(arrays)} leaves but a {src_kind!r} "
            f"state of this model has {len(refs)} (different model?)")
    for i, (ref, arr) in enumerate(zip(refs, arrays)):
        if tuple(ref.shape) != tuple(arr.shape):
            raise ValueError(
                f"cross-layout restore: canonical leaf {i} has shape "
                f"{tuple(arr.shape)} but a {src_kind!r} state of this "
                f"model stores {tuple(ref.shape)} (different model?)")
    src_state = jax.tree.unflatten(jax.tree.structure(src_t), arrays)
    repl_params, repl_opt = state_to_replicated(cfg, entry, src_state)
    ba = batch_axes(mesh)
    topo = LaneTopology(node_axes=ba[1:], lane_axis=ba[0])
    n, N = topo.sizes(mesh)
    params, opt = replicated_to_state(cfg, run, n, N, repl_params,
                                      repl_opt, kind=st.ckpt_layout.kind)
    if shardings is not None:
        params = jax.tree.map(jax.device_put, params, shardings[0])
        opt = jax.tree.map(jax.device_put, opt, shardings[1])
    return (params, opt), got


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------
# The serving RUNTIME lives in repro.serve.steps: hosting flavors are
# ("serve_step", strategy) registry cells exactly like the train-step
# table above, resolved through build_serve_step.  The two factories
# below are the unjitted lowering shims the dryrun HLO accountant uses
# (it applies its own shardings/donation and passes an external cache);
# they must stay semantically identical to the registry's "replicated"
# cell, which wraps the same model calls behind its own jit.

def build_serve_step(cfg: ModelConfig, **kw):
    """Registry-resolved serving step (see repro.serve.steps)."""
    from repro.serve.steps import build_serve_step as _build
    return _build(cfg, **kw)


def build_prefill_step(cfg: ModelConfig):
    def step(params, tokens, cache, extra=None):
        return prefill(params, cfg, tokens, cache, extra_embeds=extra)
    return step


def build_decode_step(cfg: ModelConfig):
    def step(params, token, state):
        return decode_step(params, cfg, token, state)
    return step
