"""Step builders: train (GSPMD baseline + lane-decomposed variant), serve.

`build_train_step`   — jit/GSPMD end-to-end: the "native library" baseline.
                       Optional microbatch gradient accumulation (memory
                       control at 4k×256) — grads accumulate in fp32.
`build_train_step_lane` — the paper's technique as a first-class backend:
                       shard_map manual over the batch axes (pod, data),
                       GSPMD auto over "model"; gradient sync runs through
                       repro.optim.gradsync (native / lane / lane_int8 /
                       lane_zero1).  Params replicated over batch axes in
                       this path (≤ ~10B models).
`build_prefill_step` / `build_decode_step` — serving.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core import LaneTopology
from repro.models import loss_fn, prefill, decode_step
from repro.optim import AdamWConfig, adamw_update, grad_sync
from repro.optim.gradsync import (
    _unflatten_bucket, _flatten_bucket, resolve_num_buckets,
    zero1_param_shard, zero1_unshard,
)
from .mesh import batch_axes


# ---------------------------------------------------------------------------
# GSPMD baseline train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, run: RunConfig,
                     opt: AdamWConfig, batch_axes: tuple[str, ...] = (),
                     accum_dtype=jnp.float32):
    """(params, opt_state, tokens, labels[, extra]) → (loss, params, opt).

    accum_dtype: microbatch gradient-accumulation precision.  bf16 halves
    the accumulator's HBM residency (the fp32 buffer is ~2 GB/chip for
    dbrx); stochastic error stays below the int8-DCN compression bound
    already accepted for the lane_int8 strategy.
    """

    def lf(p, tok, lab, ex):
        return loss_fn(p, cfg, tok, lab, extra_embeds=ex, remat=run.remat)

    def step(params, opt_state, tokens, labels, extra=None):
        mb = max(run.microbatch, 1)
        if mb == 1:
            loss, grads = jax.value_and_grad(lf)(params, tokens, labels, extra)
        else:
            B = tokens.shape[0]
            assert B % mb == 0, (B, mb)

            def sh(a):
                if a is None:
                    return None
                a = a.reshape(mb, B // mb, *a.shape[1:])
                if batch_axes:
                    # the (B,)→(mb, B/mb) reshape is ambiguous to GSPMD's
                    # propagation; without this constraint the per-µstep
                    # slice keeps the FULL local batch (verified: 16×
                    # activation memory on llama3.2 train_4k)
                    a = jax.lax.with_sharding_constraint(
                        a, P(None, batch_axes, *([None] * (a.ndim - 2))))
                return a

            tokens_mb, labels_mb = sh(tokens), sh(labels)
            extra_mb = sh(extra)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def acc(carry, xs):
                lsum, g = carry
                tok, lab = xs[0], xs[1]
                ex = xs[2] if len(xs) == 3 else None
                l, gi = jax.value_and_grad(lf)(params, tok, lab, ex)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g, gi)
                return (lsum + l, g), None

            xs = ((tokens_mb, labels_mb) if extra is None
                  else (tokens_mb, labels_mb, extra_mb))
            (lsum, gsum), _ = jax.lax.scan(acc, (0.0, g0), xs)
            loss = lsum / mb
            grads = jax.tree.map(lambda g: (g / mb), gsum)
        new_params, new_opt = adamw_update(opt, grads, opt_state, params)
        return loss, new_params, new_opt

    return step


# ---------------------------------------------------------------------------
# lane-decomposed train step (the paper's technique, swappable)
# ---------------------------------------------------------------------------

def build_train_step_lane(cfg: ModelConfig, run: RunConfig, opt: AdamWConfig,
                          mesh, param_specs):
    """Manual over batch axes; grad sync via repro.optim.gradsync.

    gradsync strategies: native | lane | lane_pipelined | lane_int8 |
    lane_zero1.  All lane strategies bucket the flat gradient vector
    (K = run.gradsync_buckets, 0 = cost-model auto) so the DCN lane hop of
    one bucket overlaps the ICI node collective of the next (§5 pipeline).
    lane_zero1 keeps grads + moments data-sharded through the optimizer and
    all-gathers the *updated parameters* (the paper's trailing AllGather
    moved past the update — same bytes, sharded optimizer memory); its
    shard layout is bucket-major, so param sharding/unsharding goes
    through gradsync.zero1_param_shard / zero1_unshard with the same K.
    """
    ba = batch_axes(mesh)
    topo = LaneTopology(node_axes=ba[1:] or ba, lane_axis=ba[0]) \
        if len(ba) > 1 else LaneTopology(node_axes=(ba[0],), lane_axis=ba[0])
    # single-pod fallback: treat "data" as the lane axis with a trivial
    # node level — handled by strategy below
    single = len(ba) == 1
    strategy = run.gradsync

    def lf(p, tok, lab, ex):
        return loss_fn(p, cfg, tok, lab, extra_embeds=ex, remat=run.remat)

    def per_replica(params, opt_state, tokens, labels, extra):
        loss, grads = jax.value_and_grad(lf)(params, tokens, labels, extra)
        loss = jax.lax.pmean(loss, ba)
        if single or strategy == "native":
            grads = jax.tree.map(
                lambda g: jax.lax.psum(g, ba) / _axprod(ba), grads)
            new_params, new_opt = adamw_update(opt, grads, opt_state, params)
            return loss, new_params, new_opt
        if strategy == "lane_zero1":
            total = sum(math.prod(p.shape)
                        for p in jax.tree.leaves(params))
            K = resolve_num_buckets(total, topo.n(), run.gradsync_buckets)
            shard_flat, spec = grad_sync(grads, topo, "lane_zero1",
                                         num_buckets=K)
            pflat, pspec = _flatten_bucket(params, pad_to=K * topo.n())
            mine = zero1_param_shard(pflat, topo, K)
            # sharded moments: opt_state here is the *sharded* flat state
            newp_shard, new_opt = _adamw_flat(opt, shard_flat, opt_state, mine)
            full = zero1_unshard(newp_shard, topo, K)
            new_params = _unflatten_bucket(full, pspec)
            return loss, new_params, new_opt
        grads = grad_sync(grads, topo, strategy,
                          num_buckets=run.gradsync_buckets)
        new_params, new_opt = adamw_update(opt, grads, opt_state, params)
        return loss, new_params, new_opt

    in_specs = (jax.tree.map(lambda s: _strip_batch(s, ba), param_specs),
                None, P(ba, None), P(ba, None), None)
    # NOTE: with auto={"model"} GSPMD still handles the TP dimension.
    return per_replica, topo


def _axprod(axes):
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def _strip_batch(spec, ba):
    return spec


def _adamw_flat(opt: AdamWConfig, g, state, p):
    """AdamW on a flat fp32 shard (ZeRO-1)."""
    from repro.optim.adamw import cosine_lr
    count = state["count"] + 1
    lr = cosine_lr(opt, count)
    m = opt.b1 * state["m"] + (1 - opt.b1) * g
    v = opt.b2 * state["v"] + (1 - opt.b2) * jnp.square(g)
    c1 = 1 - opt.b1 ** count.astype(jnp.float32)
    c2 = 1 - opt.b2 ** count.astype(jnp.float32)
    step = (m / c1) / (jnp.sqrt(v / c2) + opt.eps) + opt.weight_decay * p
    return p - lr * step, {"m": m, "v": v, "count": count}


def zero1_opt_init(params, topo_n: int, num_buckets: int = 0):
    """Flat sharded fp32 optimizer state for the lane_zero1 path.

    Pass ``run.gradsync_buckets`` as num_buckets: the shard size depends
    on the bucketed padding (K·n), so this MUST match the train step's
    override — resolve_num_buckets is deterministic, so the default 0
    (auto) agrees with the step's auto choice, but a nonzero override on
    one side only produces a shape mismatch inside the jitted step.
    """
    total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
    K = resolve_num_buckets(total, topo_n, num_buckets)
    padded = -(-total // (K * topo_n)) * (K * topo_n)
    sz = padded // topo_n
    return {"m": jnp.zeros((sz,), jnp.float32),
            "v": jnp.zeros((sz,), jnp.float32),
            "count": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig):
    def step(params, tokens, cache, extra=None):
        return prefill(params, cfg, tokens, cache, extra_embeds=extra)
    return step


def build_decode_step(cfg: ModelConfig):
    def step(params, token, state):
        return decode_step(params, cfg, token, state)
    return step
