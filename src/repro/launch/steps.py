"""Step builders: train (GSPMD baseline + lane-decomposed variant), serve.

`build_train_step`   — jit/GSPMD end-to-end: the "native library" baseline.
                       Optional microbatch gradient accumulation (memory
                       control at 4k×256) — grads accumulate in fp32.
`build_train_step_lane` — the paper's technique as a first-class backend:
                       shard_map manual over the batch axes (pod, data),
                       GSPMD auto over "model"; all collectives run
                       through a repro.comm.LaneComm, and the per-strategy
                       step CONSTRUCTION dispatches through the same
                       registry (@register_impl("train_step", ...) below)
                       — no strategy if-chains.  Params replicated over
                       batch axes in the non-ZeRO flavors (≤ ~10B models).
`build_prefill_step` / `build_decode_step` — serving.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import (CommConfig, LaneComm, get_impl, register_impl,
                        register_param_layout)
from repro.configs.base import ModelConfig, RunConfig
from repro.core import LaneTopology, optimal_prefetch_blocks
from repro.models import init_model, loss_fn, prefill, decode_step
from repro.models.transformer import ShardedBlocks
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import global_norm
from repro.optim.gradsync import (
    _unflatten_bucket, _flatten_bucket, decay_mask_flat, resolve_num_buckets,
    zero1_param_shard, zero1_unshard, zero3_param_shard,
)
from .mesh import batch_axes


# ---------------------------------------------------------------------------
# GSPMD baseline train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, run: RunConfig,
                     opt: AdamWConfig, batch_axes: tuple[str, ...] = (),
                     accum_dtype=jnp.float32):
    """(params, opt_state, tokens, labels[, extra]) → (loss, params, opt).

    accum_dtype: microbatch gradient-accumulation precision.  bf16 halves
    the accumulator's HBM residency (the fp32 buffer is ~2 GB/chip for
    dbrx); stochastic error stays below the int8-DCN compression bound
    already accepted for the lane_int8 strategy.
    """

    def lf(p, tok, lab, ex):
        return loss_fn(p, cfg, tok, lab, extra_embeds=ex, remat=run.remat)

    def step(params, opt_state, tokens, labels, extra=None):
        mb = max(run.microbatch, 1)
        if mb == 1:
            loss, grads = jax.value_and_grad(lf)(params, tokens, labels, extra)
        else:
            B = tokens.shape[0]
            assert B % mb == 0, (B, mb)

            def sh(a):
                if a is None:
                    return None
                a = a.reshape(mb, B // mb, *a.shape[1:])
                if batch_axes:
                    # the (B,)→(mb, B/mb) reshape is ambiguous to GSPMD's
                    # propagation; without this constraint the per-µstep
                    # slice keeps the FULL local batch (verified: 16×
                    # activation memory on llama3.2 train_4k)
                    a = jax.lax.with_sharding_constraint(
                        a, P(None, batch_axes, *([None] * (a.ndim - 2))))
                return a

            tokens_mb, labels_mb = sh(tokens), sh(labels)
            extra_mb = sh(extra)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def acc(carry, xs):
                lsum, g = carry
                tok, lab = xs[0], xs[1]
                ex = xs[2] if len(xs) == 3 else None
                l, gi = jax.value_and_grad(lf)(params, tok, lab, ex)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g, gi)
                return (lsum + l, g), None

            xs = ((tokens_mb, labels_mb) if extra is None
                  else (tokens_mb, labels_mb, extra_mb))
            (lsum, gsum), _ = jax.lax.scan(acc, (0.0, g0), xs)
            loss = lsum / mb
            grads = jax.tree.map(lambda g: (g / mb), gsum)
        new_params, new_opt = adamw_update(opt, grads, opt_state, params)
        return loss, new_params, new_opt

    return step


# ---------------------------------------------------------------------------
# lane-decomposed train step (the paper's technique, swappable)
# ---------------------------------------------------------------------------
#
# Per-strategy step CONSTRUCTION dispatches through the repro.comm
# registry too: each flavor is one @register_impl("train_step", ...)
# below, so a new gradsync variant is a registration here plus its
# grad_sync impl in repro/comm/impls.py — never an if-chain edit.  The
# builder contract: fn(comm: LaneComm, ctx: StepContext) -> step where
# step(params, opt_state, tokens, labels, extra=None) -> (loss, params,
# opt_state), traced inside shard_map with ctx.ba manual.

@dataclasses.dataclass(frozen=True)
class StepContext:
    """Everything a registered train-step builder needs besides the comm."""
    cfg: ModelConfig
    run: RunConfig
    opt: AdamWConfig
    mesh: object
    ba: tuple
    single: bool                   # one batch axis: no distinct lane level


def build_train_step_lane(cfg: ModelConfig, run: RunConfig, opt: AdamWConfig,
                          mesh, param_specs):
    """Manual over batch axes; collectives via repro.comm.LaneComm.

    The step flavor is resolved from the train_step registry by
    ``run.gradsync`` (valid names: ``repro.comm.strategies_for
    ("train_step")`` — native/lane/lane_pipelined/lane_int8/auto share
    the replicated-parameter step, lane_zero1/lane_zero3 build the
    sharded-optimizer steps; see the registrations below).  All lane
    strategies bucket the flat gradient vector (K = run.gradsync_buckets
    via CommConfig.from_run, 0 = cost-model auto) so the DCN lane hop of
    one bucket overlaps the ICI node collective of the next (§5
    pipeline); ``"auto"`` lets the cost model pick the sync strategy per
    payload and records the choice on the returned comm's ``selections``.
    On a single-batch-axis mesh the node level is trivial and every
    replicated flavor degrades to the native one-shot psum.
    ``param_specs`` is accepted for call-site compatibility but unused:
    the caller owns the shard_map in/out specs of the returned step.

    Returns ``(step, comm)``: the comm carries the topology
    (``comm.topo``), the recorded auto ``Selection``s, and the
    ``param_layout`` answer the driver keys its master state / shard
    specs / checkpoint layout off (see ``init_lane_train_state``).
    """
    ba = batch_axes(mesh)
    single = len(ba) == 1
    # single-axis meshes get an empty node level (n = 1): the lane axis
    # IS the communicator, matching the paper's N-node/1-per-node corner
    topo = LaneTopology(node_axes=ba[1:], lane_axis=ba[0])
    comm = LaneComm(topo, CommConfig.from_run(run), mesh=mesh)
    ctx = StepContext(cfg, run, opt, mesh, ba, single)
    builder = get_impl("train_step", run.gradsync)
    return builder.fn(comm, ctx), comm


def _make_loss(ctx: StepContext):
    def lf(p, tok, lab, ex):
        return loss_fn(p, ctx.cfg, tok, lab, extra_embeds=ex,
                       remat=ctx.run.remat)
    return lf


def _register_replicated(strategy: str):
    register_param_layout(strategy, "replicated")

    @register_impl("train_step", strategy, auto_ok=False)
    def _build(comm, ctx, _strategy=strategy):
        """Replicated-parameter step: full grad sync + tree AdamW."""
        lf = _make_loss(ctx)
        eff = "native" if ctx.single else _strategy

        def step(params, opt_state, tokens, labels, extra=None):
            loss, grads = jax.value_and_grad(lf)(params, tokens, labels,
                                                 extra)
            loss = jax.lax.pmean(loss, ctx.ba)
            grads = comm.grad_sync(grads, strategy=eff)
            new_params, new_opt = adamw_update(ctx.opt, grads, opt_state,
                                               params)
            return loss, new_params, new_opt
        return step
    return _build


for _s in ("native", "lane", "lane_pipelined", "lane_int8", "auto"):
    _register_replicated(_s)


register_param_layout("lane_zero1", "zero1")


@register_impl("train_step", "lane_zero1", auto_ok=False)
def _build_zero1(comm, ctx: StepContext):
    """ZeRO-1 step: data-sharded flat grads + moments through the
    optimizer; the paper's trailing AllGather moves PAST the update
    (same bytes, applied to fresh params, moments stay sharded).  The
    shard layout is bucket-major, so param sharding/unsharding goes
    through gradsync.zero1_param_shard / zero1_unshard with the same K.
    Optimizer semantics match the unsharded adamw_update exactly: the
    TRUE global grad norm is one extra scalar psum over the shard norms
    and weight decay follows the per-element matrices-only mask."""
    if ctx.single:
        return get_impl("train_step", "native").fn(comm, ctx)
    lf = _make_loss(ctx)
    topo, opt, run = comm.topo, ctx.opt, ctx.run

    def step(params, opt_state, tokens, labels, extra=None):
        loss, grads = jax.value_and_grad(lf)(params, tokens, labels, extra)
        loss = jax.lax.pmean(loss, ctx.ba)
        total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
        K = resolve_num_buckets(total, topo.n(), run.gradsync_buckets)
        shard_flat, spec = comm.grad_sync(grads, strategy="lane_zero1",
                                          num_buckets=K)
        pflat, pspec = _flatten_bucket(params, pad_to=K * topo.n())
        mine = zero1_param_shard(pflat, topo, K)
        dmask = zero1_param_shard(
            decay_mask_flat(params, pad_to=K * topo.n()), topo, K)
        # true global grad norm: shards are disjoint over the node level
        # and lane-replicated, so ONE scalar psum over the node axes sums
        # the per-shard square norms to the full-tree norm (padding
        # contributes zeros)
        gnorm = jnp.sqrt(jax.lax.psum(jnp.sum(jnp.square(shard_flat)),
                                      topo.node_axes))
        scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
        # sharded moments: opt_state here is the *sharded* flat state
        newp_shard, new_opt = _adamw_flat(opt, shard_flat, opt_state, mine,
                                          scale=scale, decay_mask=dmask)
        full = zero1_unshard(newp_shard, topo, K)
        new_params = _unflatten_bucket(full, pspec)
        return loss, new_params, new_opt
    return step


register_param_layout("lane_zero3", "zero3")


@register_impl("train_step", "lane_zero3", auto_ok=False)
def _build_zero3(comm, ctx: StepContext):
    """ZeRO-3/FSDP step: the scanned layer stack stays sharded 1/p per
    chip (zero3_shard_blocks layout) and is re-gathered LAYER BY LAYER
    inside the forward scan via comm.prefetch_allgather — the pipelined
    AG(lane)→AG(node) with a one-layer prefetch buffer so layer i+1's
    gather overlaps layer i's compute (run.fsdp_prefetch: 0 = cost-model
    block count, >0 = override, -1 = blocking negative control, which
    dispatches to the registry's "blocking" gather).  Gradients for the
    stack need no separate sync: the gather's AD transpose IS the
    lane_zero3 reduce-scatter.  Optimizer semantics match native: one
    scalar psum over the (lane × node) shard norms recovers the true
    global grad norm for clipping, and the flat decay mask reproduces
    matrices-only weight decay."""
    ba, run, opt = ctx.ba, ctx.run, ctx.opt
    if len(ba) < 2:
        # zero3 shards over the (lane × node) product and its gather
        # pipeline needs the two levels to be DISTINCT axes; there is no
        # sensible single-axis degradation (unlike the other strategies,
        # which fall back to native)
        raise ValueError(
            "lane_zero3 needs distinct lane and node batch axes (a "
            "multi-pod mesh); use native or lane_zero1 on single-"
            f"batch-axis meshes (got batch axes {ba})")
    topo = comm.topo
    lf = _make_loss(ctx)
    n_, N_ = topo.sizes(ctx.mesh)
    spec3 = zero3_layer_spec(ctx.cfg)
    B3 = resolve_prefetch_blocks(spec3.layer_elems, n_, N_,
                                 run.fsdp_prefetch)
    blocking = run.fsdp_prefetch == -1

    def gather_layer(x):
        return unflatten_layer(comm.prefetch_allgather(x, num_blocks=B3),
                               spec3)

    def step(params, opt_state, tokens, labels, extra=None):
        """lane_zero3 train step.

        params["blocks"] is this chip's shard — any shape reshapeable
        to (L, B·s), e.g. the local block of the host-side
        (L, B, n·N, s) layout from zero3_shard_blocks.  opt_state is
        the split {"rest", "blocks"} state of zero3_opt_init.  The
        returned params keep the blocks SHARDED (same shape as the
        input): ZeRO-3 never materializes full parameters outside the
        per-layer prefetch window.
        """
        bshape = params["blocks"].shape
        shards = params["blocks"].reshape(spec3.num_layers, -1)
        rest = {k: v for k, v in params.items() if k != "blocks"}

        def lf3(rest_p, sh):
            p = dict(rest_p)
            p["blocks"] = ShardedBlocks(sh, gather_layer,
                                        prefetch=not blocking)
            return lf(p, tokens, labels, extra)

        loss, (g_rest, g_sh) = jax.value_and_grad(
            lf3, argnums=(0, 1))(rest, shards)
        loss = jax.lax.pmean(loss, ba)
        # the gather's transpose already reduce-scattered g_sh over
        # (lane × node) — sum over replicas; only the mean is left
        g_sh = g_sh / _axprod(ba)
        g_rest = comm.grad_sync(g_rest, strategy="lane")
        # true global grad norm over rest + blocks: the 1/p stripes are
        # disjoint, so one scalar psum over BOTH levels totals their
        # square norms; g_rest is fully reduced (replicated), added once
        gsq_sh = jax.lax.psum(jnp.sum(jnp.square(g_sh)),
                              (topo.lane_axis, *topo.node_axes))
        gnorm = jnp.sqrt(gsq_sh + global_norm(g_rest) ** 2)
        scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
        new_rest, new_opt_rest = adamw_update(
            opt, g_rest, opt_state["rest"], rest, grad_norm=gnorm)
        shard_len = shards.shape[1]
        dmask = jnp.tile(
            zero3_param_shard(
                _zero3_decay_mask(spec3, pad_to=shard_len * topo.p()),
                topo, B3),
            spec3.num_layers)
        ob = opt_state["blocks"]
        newp, nob = _adamw_flat(
            opt, g_sh.reshape(-1),
            {"m": ob["m"].reshape(-1), "v": ob["v"].reshape(-1),
             "count": ob["count"]},
            shards.reshape(-1), scale=scale, decay_mask=dmask)
        new_params = dict(new_rest)
        new_params["blocks"] = newp.reshape(bshape)
        new_opt = {"rest": new_opt_rest,
                   "blocks": {"m": nob["m"].reshape(ob["m"].shape),
                              "v": nob["v"].reshape(ob["v"].shape),
                              "count": nob["count"]}}
        return loss, new_params, new_opt
    return step


def _axprod(axes):
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def _zero3_decay_mask(spec3, pad_to: int):
    """Per-layer 0/1 decay mask in the flat layer layout: 1 where the
    stacked (L, ...) leaf has ndim >= 2 (len(shape[1:]) >= 1) — the
    leaves adamw_update decays in the replicated step.  Padding is 0."""
    parts = [jnp.full((math.prod(s),), 1.0 if len(s) >= 1 else 0.0,
                      jnp.float32)
             for s, _ in spec3.metas]
    m = jnp.concatenate(parts)
    pad = pad_to - m.shape[0]
    if pad:
        m = jnp.concatenate([m, jnp.zeros((pad,), jnp.float32)])
    return m


def _adamw_flat(opt: AdamWConfig, g, state, p, *, scale=None,
                decay_mask=None):
    """AdamW on a flat fp32 shard (ZeRO-1 / ZeRO-3).

    scale: global-norm clip factor — the CALLER computes it from the true
    global norm (one extra scalar psum over shard norms) so every shard
    clips by the same full-model scale, exactly like adamw_update; None
    skips clipping.  decay_mask: 0/1 per-element mask of the leaves
    adamw_update would decay (matrices; see gradsync.decay_mask_flat);
    None decays every element uniformly (legacy behavior, kept for bare
    callers)."""
    from repro.optim.adamw import cosine_lr
    count = state["count"] + 1
    lr = cosine_lr(opt, count)
    if scale is not None:
        g = g * scale
    m = opt.b1 * state["m"] + (1 - opt.b1) * g
    v = opt.b2 * state["v"] + (1 - opt.b2) * jnp.square(g)
    c1 = 1 - opt.b1 ** count.astype(jnp.float32)
    c2 = 1 - opt.b2 ** count.astype(jnp.float32)
    decay = opt.weight_decay * p
    if decay_mask is not None:
        decay = decay * decay_mask
    step = (m / c1) / (jnp.sqrt(v / c2) + opt.eps) + decay
    return p - lr * step, {"m": m, "v": v, "count": count}


def zero1_opt_init(params, topo_n: int, num_buckets: int = 0):
    """Flat sharded fp32 optimizer state for the lane_zero1 path.

    Pass ``run.gradsync_buckets`` as num_buckets: the shard size depends
    on the bucketed padding (K·n), so this MUST match the train step's
    override — resolve_num_buckets is deterministic, so the default 0
    (auto) agrees with the step's auto choice, but a nonzero override on
    one side only produces a shape mismatch inside the jitted step.
    """
    total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
    K = resolve_num_buckets(total, topo_n, num_buckets)
    padded = -(-total // (K * topo_n)) * (K * topo_n)
    sz = padded // topo_n
    return {"m": jnp.zeros((sz,), jnp.float32),
            "v": jnp.zeros((sz,), jnp.float32),
            "count": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# ZeRO-3 layer sharding (the lane_zero3 / FSDP path)
# ---------------------------------------------------------------------------
#
# The scanned layer stack params["blocks"] (every leaf (L, ...)) is
# flattened per layer into an (L, D) fp32 master copy, padded to
# D_pad = B·n·N·s, and each chip keeps the (L, B·s) stripe of the
# gradsync.zero3_param_shard layout.  The host-side array is shaped
# (L, B, n·N, s) so a plain NamedSharding P(None, None, (*node_axes,
# lane_axis), None) places exactly stripe (node_rank·N + lane_rank) on
# each chip — no host-side rank arithmetic.  Everything that both sides
# of the shard_map boundary must agree on (leaf order, D, B, s) is
# derived deterministically from the ModelConfig via zero3_layer_spec.

class Zero3LayerSpec:
    """Flat layout of ONE layer's parameter tree (derived via eval_shape,
    so it never materializes weights)."""

    def __init__(self, metas, treedef, layer_elems: int, num_layers: int):
        self.metas = metas              # ((shape[1:], dtype) per leaf)
        self.treedef = treedef
        self.layer_elems = layer_elems  # D: unpadded flat size per layer
        self.num_layers = num_layers


def zero3_layer_spec(cfg: ModelConfig) -> Zero3LayerSpec:
    abs_params = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))
    leaves, treedef = jax.tree.flatten(abs_params["blocks"])
    metas = tuple((tuple(l.shape[1:]), l.dtype) for l in leaves)
    elems = sum(math.prod(s) for s, _ in metas)
    return Zero3LayerSpec(metas, treedef, elems, leaves[0].shape[0])


def unflatten_layer(vec, spec: Zero3LayerSpec):
    """Padded flat fp32 layer vector -> the layer's parameter tree (leaves
    cast back to their stored dtypes)."""
    out, ofs = [], 0
    for shape, dtype in spec.metas:
        sz = math.prod(shape)
        out.append(vec[ofs:ofs + sz].reshape(shape).astype(dtype))
        ofs += sz
    return jax.tree.unflatten(spec.treedef, out)


def resolve_prefetch_blocks(layer_elems: int, n: int, N: int,
                            override: int = 0) -> int:
    """The B every lane_zero3 call site uses (shard layout, opt-state
    size, per-layer gather pipeline).  override > 0 wins; -1 (blocking
    negative control) gathers monolithically so B degenerates to 1;
    otherwise the cost model picks B from the DCN latency/bandwidth
    crossover on the per-chip stripe.  Capped so each block keeps at
    least one row per chip."""
    p = max(n * N, 1)
    if override > 0:
        b = override
    elif override < 0:
        b = 1
    else:
        b = optimal_prefetch_blocks(layer_elems * 4 / p)
    return max(1, min(b, max(1, layer_elems // p)))


def _flatten_blocks_layerwise(blocks, pad_to: int):
    leaves, _ = jax.tree.flatten(blocks)
    L = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(L, -1).astype(jnp.float32) for l in leaves], axis=1)
    pad = (-flat.shape[1]) % pad_to
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((L, pad), flat.dtype)], axis=1)
    return flat


def zero3_shard_blocks(blocks, n: int, N: int, fsdp_prefetch: int = 0):
    """Host-side: the (L, B, n·N, s) fp32 master layout of the stacked
    layer tree.  Place on the mesh with
    ``P(None, None, (*node_axes, lane_axis), None)`` and each chip's
    local block reshapes to the (L, B·s) shard the train step expects.
    Returns (array, B)."""
    leaves = jax.tree.leaves(blocks)
    L = leaves[0].shape[0]
    elems = sum(math.prod(l.shape[1:]) for l in leaves)
    B = resolve_prefetch_blocks(elems, n, N, fsdp_prefetch)
    p = n * N
    flat = _flatten_blocks_layerwise(blocks, pad_to=B * p)
    s = flat.shape[1] // (B * p)
    return flat.reshape(L, B, p, s), B


def zero3_opt_init(params, n: int, N: int, fsdp_prefetch: int = 0):
    """Split optimizer state for the lane_zero3 step: ordinary AdamW tree
    state for the replicated non-block params, flat sharded fp32 moments
    (in the zero3_shard_blocks layout) for the layer stack.  The B
    resolution MUST match the step's (resolve_prefetch_blocks is
    deterministic, so the default 0 agrees; pass the same
    run.fsdp_prefetch override on both sides)."""
    blocks = params["blocks"]
    rest = {k: v for k, v in params.items() if k != "blocks"}
    # derive the moment shape FROM zero3_shard_blocks (via eval_shape, no
    # weight materialization) so the layout invariant lives in one place
    shard = jax.eval_shape(
        lambda b: zero3_shard_blocks(b, n, N, fsdp_prefetch)[0], blocks)
    zeros = jnp.zeros(shard.shape, jnp.float32)
    return {"rest": adamw_init(rest),
            "blocks": {"m": zeros, "v": zeros,
                       "count": jnp.zeros((), jnp.int32)}}


# ---------------------------------------------------------------------------
# driver-side master state: layout-aware init + shard specs + ckpt layout
# ---------------------------------------------------------------------------
#
# Everything the training driver must agree on with the jitted step —
# which master layout the params/optimizer state live in, the shard_map
# in/out PartitionSpecs of that layout, and the checkpoint layout that
# canonicalizes it — is derived HERE from the same LaneComm.param_layout
# answer the step builders register, so a new strategy's driver wiring is
# its register_param_layout(...) line, not a fourth if-chain.

@dataclasses.dataclass
class LaneTrainState:
    """Host-side master state for one lane train-step flavor.

    params/opt_state: host (global-view) arrays in the step's master
        layout — device_put against ``to_shardings(mesh)`` before use.
    pspecs/ospecs: the matching shard_map in/out PartitionSpec trees.
    ckpt_layout: the repro.checkpoint layout that canonicalizes this
        state on disk (thread into AsyncCheckpointer/restore_checkpoint).
    """
    params: object
    opt_state: object
    pspecs: object
    ospecs: object
    ckpt_layout: object

    def to_shardings(self, mesh):
        from jax.sharding import NamedSharding
        mk = lambda specs: jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return mk(self.pspecs), mk(self.ospecs)


def zero1_checkpoint_layout(params, n: int, num_buckets: int = 0):
    """Checkpoint layout of the lane_zero1 flat optimizer moments (the
    SAME K/padding resolution as zero1_opt_init and the train step)."""
    from repro.checkpoint import Zero1CheckpointLayout
    total = sum(math.prod(p.shape) for p in jax.tree.leaves(params))
    K = resolve_num_buckets(total, n, num_buckets)
    return Zero1CheckpointLayout(total, K, n)


def zero3_checkpoint_layout(cfg: ModelConfig, n: int, N: int,
                            fsdp_prefetch: int = 0):
    """Checkpoint layout of the lane_zero3 (L, B, p, s) masters (the SAME
    B resolution as zero3_shard_blocks / zero3_opt_init / the step)."""
    from repro.checkpoint import Zero3CheckpointLayout
    spec3 = zero3_layer_spec(cfg)
    B = resolve_prefetch_blocks(spec3.layer_elems, n, N, fsdp_prefetch)
    return Zero3CheckpointLayout(spec3.num_layers, spec3.layer_elems, B,
                                 max(n * N, 1))


def init_lane_train_state(cfg: ModelConfig, run: RunConfig, mesh,
                          params, comm: LaneComm = None) -> LaneTrainState:
    """Master state + specs + checkpoint layout for ``run.gradsync``.

    ``params`` is the replicated init_model tree; the ZeRO flavors
    re-lay it out host-side (zero3_shard_blocks) and build fresh sharded
    optimizer state.  Pass the ``comm`` returned by
    ``build_train_step_lane`` so the layout/topology decision is read off
    the SAME object the step was built against (None re-derives it from
    the mesh — identical by construction, for callers without a step).
    """
    from repro.checkpoint import REPLICATED
    if comm is None:
        ba = batch_axes(mesh)
        topo = LaneTopology(node_axes=ba[1:], lane_axis=ba[0])
        comm = LaneComm(topo, CommConfig.from_run(run), mesh=mesh)
    topo = comm.topo
    kind = comm.param_layout(run.gradsync)
    n, N = topo.sizes(mesh)
    pspecs = jax.tree.map(lambda _: P(), params)
    if kind == "replicated":
        opt = adamw_init(params)
        return LaneTrainState(params, opt, pspecs,
                              jax.tree.map(lambda _: P(), opt), REPLICATED)
    if kind == "zero1":
        layout = zero1_checkpoint_layout(params, n, run.gradsync_buckets)
        opt = {"m": jnp.zeros((layout.padded,), jnp.float32),
               "v": jnp.zeros((layout.padded,), jnp.float32),
               "count": jnp.zeros((), jnp.int32)}
        ospecs = {"m": P(topo.node_axes), "v": P(topo.node_axes),
                  "count": P()}
        return LaneTrainState(params, opt, pspecs, ospecs, layout)
    assert kind == "zero3", kind
    shards, B = zero3_shard_blocks(params["blocks"], n, N,
                                   run.fsdp_prefetch)
    layout = zero3_checkpoint_layout(cfg, n, N, run.fsdp_prefetch)
    if tuple(shards.shape) != layout.master_shape or B != layout.num_blocks:
        # both sides derive B/padding from the layer element count; if
        # the real block tree and zero3_layer_spec ever disagree the
        # checkpoint would silently record the wrong geometry
        raise ValueError(
            f"zero3 master layout drift: sharded blocks {shards.shape} "
            f"(B={B}) vs checkpoint layout {layout.master_shape} "
            f"(B={layout.num_blocks})")
    p3 = {k: v for k, v in params.items() if k != "blocks"}
    p3["blocks"] = shards
    opt = zero3_opt_init(params, n, N, run.fsdp_prefetch)
    master_spec = P(None, None, (*topo.node_axes, topo.lane_axis), None)
    pspecs = jax.tree.map(lambda _: P(), p3)
    pspecs["blocks"] = master_spec
    ospecs = jax.tree.map(lambda _: P(), opt)
    ospecs["blocks"]["m"] = ospecs["blocks"]["v"] = master_spec
    return LaneTrainState(p3, opt, pspecs, ospecs, layout)


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig):
    def step(params, tokens, cache, extra=None):
        return prefill(params, cfg, tokens, cache, extra_embeds=extra)
    return step


def build_decode_step(cfg: ModelConfig):
    def step(params, token, state):
        return decode_step(params, cfg, token, state)
    return step
