"""Multi-host cluster launch: per-host process plans for real pods.

A v5e-256 pod is 64 hosts × 4 chips; the 2-pod production mesh is 128
hosts.  Every host runs the SAME entry point (train.py / serve.py) under
`jax.distributed.initialize(coordinator, num_processes, process_id)`;
JAX then exposes all 512 chips as global devices and
`make_production_mesh(multi_pod=True)` works unchanged — nothing in the
model/step code is host-aware except the data loader, which takes
(host_index, num_hosts) from this plan.

`plan_cluster()` is pure (unit-tested): it emits the per-host environment
+ argv, the restart policy, and the elastic-shrink handoff (which hosts
survive a pod loss and what mesh they rebuild — runtime/elastic.py).
`render_*` emit ready-to-submit artifacts for the two launchers we target:
a GKE JobSet manifest and a plain SSH/pdsh script.  On preemption, every
host receives SIGTERM → train.py's emergency checkpoint fires; the
restarted JobSet resumes from the latest committed step (the data
pipeline is (seed, step)-keyed so no sample is skipped or repeated).
"""
from __future__ import annotations

import dataclasses
import json
import shlex
from typing import Sequence

CHIPS_PER_HOST = 4


@dataclasses.dataclass(frozen=True)
class HostPlan:
    host_index: int
    pod_index: int
    process_id: int
    env: dict
    argv: tuple[str, ...]


def plan_cluster(*, num_pods: int = 2, hosts_per_pod: int = 64,
                 coordinator: str = "pod0-host0:8476",
                 module: str = "repro.launch.train",
                 extra_args: Sequence[str] = ()) -> list[HostPlan]:
    """One HostPlan per host; process_id is pod-major (matches the mesh's
    device order so the "pod" axis is the slow DCN dimension)."""
    total = num_pods * hosts_per_pod
    plans = []
    for pod in range(num_pods):
        for h in range(hosts_per_pod):
            pid = pod * hosts_per_pod + h
            env = {
                "JAX_COORDINATOR_ADDRESS": coordinator,
                "JAX_NUM_PROCESSES": str(total),
                "JAX_PROCESS_ID": str(pid),
                # TPU runtime picks local chips up automatically; these
                # document the topology for the data loader + logs
                "REPRO_HOST_INDEX": str(pid),
                "REPRO_NUM_HOSTS": str(total),
                "REPRO_POD_INDEX": str(pod),
            }
            argv = ("python", "-m", module, *extra_args)
            plans.append(HostPlan(pid, pod, pid, env, argv))
    return plans


def surviving_plans(plans: list[HostPlan], lost_pods: Sequence[int]
                    ) -> list[HostPlan]:
    """Elastic shrink after pod loss: re-number the survivors so the
    rebuilt (smaller) mesh has consecutive process ids; pairs with
    runtime.plan_elastic_mesh for the device-side shrink."""
    lost = set(lost_pods)
    keep = [p for p in plans if p.pod_index not in lost]
    out = []
    for new_pid, p in enumerate(keep):
        env = dict(p.env)
        env["JAX_PROCESS_ID"] = str(new_pid)
        env["JAX_NUM_PROCESSES"] = str(len(keep))
        env["REPRO_HOST_INDEX"] = str(new_pid)
        env["REPRO_NUM_HOSTS"] = str(len(keep))
        out.append(HostPlan(new_pid, p.pod_index, new_pid, env, p.argv))
    return out


def render_ssh_script(plans: list[HostPlan], hostname_fmt: str =
                      "pod{pod}-host{host}") -> str:
    """Plain pdsh/ssh fan-out (small clusters, bring-up debugging)."""
    lines = ["#!/usr/bin/env bash", "set -euo pipefail", ""]
    for p in plans:
        host = hostname_fmt.format(pod=p.pod_index,
                                   host=p.host_index % 64)
        envs = " ".join(f"{k}={shlex.quote(v)}" for k, v in p.env.items())
        cmd = " ".join(shlex.quote(a) for a in p.argv)
        lines.append(f"ssh {host} {shlex.quote(f'{envs} {cmd}')} &")
    lines += ["", "wait"]
    return "\n".join(lines) + "\n"


def render_gke_jobset(plans: list[HostPlan], *, image: str,
                      name: str = "lanecoll-train") -> str:
    """GKE JobSet manifest (the production path): one replicated job per
    pod slice; TPU webhook injects the per-host env; restartPolicy
    recreates the whole set on any host failure, and train.py resumes
    from the latest committed checkpoint."""
    num_pods = max(p.pod_index for p in plans) + 1
    hosts = sum(1 for p in plans if p.pod_index == 0)
    manifest = {
        "apiVersion": "jobset.x-k8s.io/v1alpha2",
        "kind": "JobSet",
        "metadata": {"name": name},
        "spec": {
            "failurePolicy": {"maxRestarts": 10},
            "replicatedJobs": [{
                "name": "pod",
                "replicas": num_pods,
                "template": {"spec": {
                    "parallelism": hosts, "completions": hosts,
                    "backoffLimit": 0,
                    "template": {"spec": {
                        "terminationGracePeriodSeconds": 120,  # SIGTERM ckpt
                        "nodeSelector": {
                            "cloud.google.com/gke-tpu-accelerator":
                                "tpu-v5-lite-podslice",
                            "cloud.google.com/gke-tpu-topology": "16x16",
                        },
                        "containers": [{
                            "name": "worker", "image": image,
                            "command": list(plans[0].argv),
                            "resources": {"limits":
                                          {"google.com/tpu": CHIPS_PER_HOST}},
                        }],
                    }},
                }},
            }],
        },
    }
    return json.dumps(manifest, indent=1)


def maybe_initialize_distributed() -> dict:
    """Call at the top of train/serve on real fleets; no-op on one host."""
    import os
    addr = os.environ.get("JAX_COORDINATOR_ADDRESS")
    if not addr:
        return {"distributed": False, "host_index": 0, "num_hosts": 1}
    import jax
    jax.distributed.initialize(
        coordinator_address=addr,
        num_processes=int(os.environ["JAX_NUM_PROCESSES"]),
        process_id=int(os.environ["JAX_PROCESS_ID"]))
    return {"distributed": True,
            "host_index": int(os.environ["REPRO_HOST_INDEX"]),
            "num_hosts": int(os.environ["REPRO_NUM_HOSTS"])}
