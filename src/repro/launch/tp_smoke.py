import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (device count locks
# at first backend init) — this module is a standalone CI entry point.
"""CI leg: the THIRD parallelism axis through the real training driver.

Sweeps ``--model-parallel`` (tensor parallelism over the mesh's 'model'
axis — mlp_tp activation collectives through registry cells) and
``--expert-parallel`` (MoE token routing as the decomposed moe_route
alltoall, never-gathered (L, E/p) expert master) over a dense and a MoE
arch, each cell a fresh 2-step run committing a checkpoint plus a
resumed run that must restore it — a third-axis configuration the driver
cannot actually train + checkpoint + restore fails the build here.  The
EP cell also sweeps ``--ep-blocks 2`` (the §5 capacity-pipelined routing
whose alltoall/FFN overlap is HLO-pinned in collective_cases).

The bit-identity of these runs against their TP=1 / gather-MoE baselines
is pinned in testing/collective_cases.py and testing/driver_cases.py;
this leg certifies the DRIVER surface end to end.

Usage:  python -m repro.launch.tp_smoke   (wired into ``make ci``)
"""
import sys                                                    # noqa: E402
import tempfile                                               # noqa: E402


# (name, arch, gradsync, extra args) — TP over dense for both replicated
# and zero3 step flavors; EP over the MoE smoke arch (lane_zero3 is the
# flavor with the never-gathered expert master; 'lane' slices experts
# from the replicated tree); EP with the pipelined routing depth
CELLS = [
    ("tp2_lane[dense]", "llama3.2-3b", "lane", ["--model-parallel", "2"]),
    ("tp2_zero3[dense]", "llama3.2-3b", "lane_zero3",
     ["--model-parallel", "2"]),
    ("ep_lane[moe]", "dbrx-132b", "lane", ["--expert-parallel"]),
    ("ep_zero3[moe]", "dbrx-132b", "lane_zero3", ["--expert-parallel"]),
    ("ep_zero3_blocks2[moe]", "dbrx-132b", "lane_zero3",
     ["--expert-parallel", "--ep-blocks", "2"]),
]


def main(argv=None) -> int:
    from repro.checkpoint import latest_step
    from repro.launch.train import main as train_main

    fails = []
    for name, arch, gradsync, extra in CELLS:
        print(f"=== tp-smoke {name} ===", flush=True)
        try:
            with tempfile.TemporaryDirectory() as td:
                ck = f"{td}/ck"
                base = ["--arch", arch, "--smoke", "--batch", "8",
                        "--seq", "16", "--ckpt", ck, "--ckpt-every", "2",
                        "--log-every", "1", "--gradsync", gradsync,
                        "--pods", "2", *extra]
                rc = train_main([*base, "--steps", "2"])
                if rc != 0 or latest_step(ck) != 2:
                    raise RuntimeError(
                        f"fresh run failed: rc={rc}, "
                        f"step={latest_step(ck)}")
                rc = train_main([*base, "--steps", "3"])    # restore path
                if rc != 0 or latest_step(ck) != 3:
                    raise RuntimeError(
                        f"restore run failed: rc={rc}, "
                        f"step={latest_step(ck)}")
        except Exception as e:  # noqa: BLE001
            fails.append(name)
            print(f"FAIL {name}: {e!r}", flush=True)
        else:
            print(f"PASS {name}", flush=True)
    print(f"tp-smoke: {len(CELLS) - len(fails)}/{len(CELLS)} cells OK"
          + (f"; FAILED {fails}" if fails else ""))
    return len(fails)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
