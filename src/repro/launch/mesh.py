"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets the host-device-count flag before any
jax initialization; everyone else sees the real devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod; the multi-pod mesh adds the cross-DCN "pod" axis.

    Axis roles: "pod" = cross-pod DCN (the paper's lane level is *across*
    this axis: each intra-pod chip is one lane), "data" = batch parallelism
    (intra-pod ICI), "model" = tensor parallelism (intra-pod ICI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh with the same axis names, for 8-device CPU testing."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
