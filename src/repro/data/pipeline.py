"""Deterministic, restartable, sharded data pipeline.

Design points for 1000+ nodes:
  * Every batch is a pure function of (seed, step) — no iterator state to
    checkpoint, no skew after restart: a resumed job at step S regenerates
    exactly the batch a non-failed job would have seen.
  * Each host materializes only its own rows (host_rows = global_batch /
    num_hosts); the arrays are handed to jax with the global sharding, so
    no host ever holds the global batch.
  * Backed by either a memory-mapped token file (production) or a seeded
    synthetic stream (benchmarks/tests) behind one interface.
  * Prefetch: a single background thread keeps `depth` batches ready —
    enough to hide host-side indexing behind device steps.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


class TokenDataset:
    """Memory-mapped flat token file (np.uint16/uint32 raw)."""

    def __init__(self, path: str, dtype=np.uint16, vocab_size: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab_size = vocab_size or int(self.tokens.max()) + 1

    def __len__(self) -> int:
        return len(self.tokens)

    def window(self, offset: int, length: int) -> np.ndarray:
        offset = int(offset) % max(len(self.tokens) - length - 1, 1)
        return np.asarray(self.tokens[offset:offset + length + 1],
                          dtype=np.int32)


class SyntheticLM:
    """Seeded synthetic token stream — a Zipf-ish unigram LM with enough
    structure (copy runs) that loss decreases measurably when training."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        rng = np.random.default_rng(seed)
        z = rng.zipf(1.3, size=vocab_size).astype(np.float64)
        self.probs = z / z.sum()

    def window(self, offset: int, length: int) -> np.ndarray:
        rng = np.random.default_rng(np.uint64(offset) * 2654435761 % 2**63)
        toks = rng.choice(self.vocab_size, size=length + 1, p=self.probs)
        # inject copy structure: second half of each 64-run repeats first
        toks = toks.reshape(-1, 64) if (length + 1) % 64 == 0 else toks
        if toks.ndim == 2:
            toks[:, 32:] = toks[:, :32]
            toks = toks.reshape(-1)
        return toks.astype(np.int32)


@dataclasses.dataclass
class ShardedLoader:
    """Deterministic (seed, step) → host-local batch."""
    source: object                  # TokenDataset | SyntheticLM
    seq_len: int
    global_batch: int
    host_index: int = 0
    num_hosts: int = 1
    seed: int = 0

    def host_rows(self) -> int:
        if self.global_batch % self.num_hosts:
            raise ValueError(
                f"global_batch={self.global_batch} must divide evenly "
                f"across num_hosts={self.num_hosts}")
        return self.global_batch // self.num_hosts

    def batch_slice(self, step: int, row0: int, rows: int):
        """(tokens, labels) for global rows [row0, row0+rows) of ``step``.

        Rows are keyed by (seed, step, global_row) alone — NOT by which
        host asks — so any host can regenerate any other host's slice.
        This is what makes quorum-dropped microbatches replayable: the
        rows a masked pod never contributed are a pure function of
        (seed, step, that pod's row range), and a later step (or an
        offline audit) re-materializes exactly them.
        """
        toks = np.empty((rows, self.seq_len + 1), np.int32)
        for r in range(rows):
            # offset mixes (seed, step, global_row) — restart-stable
            g = row0 + r
            offset = (np.uint64(self.seed) * np.uint64(0x9E3779B97F4A7C15)
                      + np.uint64(step) * np.uint64(self.global_batch)
                      + np.uint64(g)) * np.uint64(self.seq_len)
            toks[r] = self.source.window(int(offset % (2**62)), self.seq_len)
        return toks[:, :-1].copy(), toks[:, 1:].copy()

    def batch_at(self, step: int):
        """(tokens, labels) for this host, shape (host_rows, seq_len)."""
        rows = self.host_rows()
        return self.batch_slice(step, self.host_index * rows, rows)

    def prefetch(self, start_step: int, depth: int = 2) -> Iterator:
        """Background-threaded iterator of (step, tokens, labels)."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                item = (s, *self.batch_at(s))
                q.put(item)
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_loader(cfg, seq_len: int, global_batch: int, *, path: str = "",
                seed: int = 0, host_index: int = 0,
                num_hosts: int = 1) -> ShardedLoader:
    src = (TokenDataset(path, vocab_size=cfg.vocab_size) if path
           else SyntheticLM(cfg.vocab_size, seed))
    return ShardedLoader(src, seq_len, global_batch,
                         host_index=host_index, num_hosts=num_hosts,
                         seed=seed)
