from .pipeline import (TokenDataset, SyntheticLM, ShardedLoader,
                       make_loader)
