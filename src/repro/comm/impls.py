"""Registered implementations of every LaneComm collective.

This module IS the dispatch table that used to live as ``if`` chains in
``optim/gradsync.py:grad_sync``: each (collective, strategy) cell is one
``@register_impl`` registration wrapping the §3 mock-ups
(:mod:`repro.core.collectives`), the §5 pipelined constructions
(:mod:`repro.core.pipeline`) and the bucketed gradient-sync machinery
(:mod:`repro.optim.gradsync` — which keeps the layout/packing helpers
and is now a thin deprecation shim around this table).

Registration legend per collective:

  native           one-shot over the product communicator (the baseline
                   the paper's decompositions are measured against)
  lane             full-lane mock-up (Listings 1-6)
  lane_pipelined   §5 pipelined construction (allreduce/bcast/reduce;
                   bcast/reduce rings are rooted at lane 0, so they are
                   never auto-selected)
  grad_sync        the composite training collective; six strategies
                   (native/lane/lane_pipelined/lane_int8/lane_zero1/
                   lane_zero3), the ZeRO ones returning (shard, spec)
  prefetch_allgather
                   lane_pipelined (the ZeRO-3 weight prefetch) and
                   blocking (the monolithic negative control)

Model families plug into the ZeRO-3 runtime through the SAME registry
seam under the ``"block_stack"`` collective (one
``@register_block_stack(family)`` per family — see
:mod:`repro.models.blockstack`; the specs live next to the block bodies
in :mod:`repro.models.transformer`), so the backward re-gather, the
ssm/hybrid/moe stacks and the zero3-sharded embeddings all ride the
registered ``prefetch_allgather`` implementations below without new
cells here.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as C
from repro.core.costmodel import optimal_prefetch_blocks
from repro.core.lane import LaneTopology
from repro.core.pipeline import (
    _pipelined_allreduce_lane, pipelined_allgather_lane,
    pipelined_bcast_lane, pipelined_reduce_lane,
)
from repro.optim.gradsync import (
    _ag_node, _ar_lane, _ar_lane_int8, _flatten_bucket, _rs_node,
    _unflatten_bucket, bucket_schedule, resolve_num_buckets, zero3_unshard,
)

from . import costs
from .registry import register_impl

__all__ = []  # everything is reached through the registry


# ---------------------------------------------------------------------------
# feasibility predicates (leading-dim divisibility of the §3 mock-ups)
# ---------------------------------------------------------------------------

def _div_n(n, N, lead):
    return lead % max(n, 1) == 0


def _div_p(n, N, lead):
    return lead % max(n * N, 1) == 0


def _axes(topo: LaneTopology):
    return (topo.lane_axis, *topo.node_axes)


def _nrep(topo: LaneTopology) -> int:
    r = 1
    for a in _axes(topo):
        r *= lax.axis_size(a)
    return r


# ---------------------------------------------------------------------------
# allreduce
# ---------------------------------------------------------------------------

@register_impl("allreduce", "native", cost=costs.native_cost("allreduce"))
def _allreduce_native(comm, x):
    return C.native_allreduce(x, comm.topo)


@register_impl("allreduce", "lane", cost=costs.lane_cost("allreduce"),
               feasible=_div_n)
def _allreduce_lane(comm, x):
    return C.allreduce_lane(x, comm.topo)


@register_impl("allreduce", "lane_pipelined",
               cost=costs.cost_pipelined_allreduce, feasible=_div_n)
def _allreduce_pipelined(comm, x, *, num_blocks=None):
    """§5 pipelined allreduce; num_blocks None = cost-model K shrunk to
    the nearest divisor of the per-chip block count (explicit values keep
    the legacy strict-divisibility contract)."""
    n = comm.topo.n()
    lead = x.shape[0]
    if num_blocks is None:
        B = resolve_num_buckets(lead, n, comm.cfg.buckets)
        while lead % (B * n):
            B -= 1
        num_blocks = max(B, 1)
    return _pipelined_allreduce_lane(x, comm.topo, num_blocks=num_blocks)


# ---------------------------------------------------------------------------
# reduce_scatter / allgather / alltoall / scan
# ---------------------------------------------------------------------------

@register_impl("reduce_scatter", "native",
               cost=costs.native_cost("reduce_scatter"), feasible=_div_p)
def _rs_native(comm, x):
    return C.native_reduce_scatter(x, comm.topo)


@register_impl("reduce_scatter", "lane",
               cost=costs.lane_cost("reduce_scatter"), feasible=_div_p)
def _rs_lane(comm, x):
    return C.reduce_scatter_lane(x, comm.topo)


@register_impl("allgather", "native", cost=costs.native_cost("allgather"))
def _ag_native(comm, x):
    return C.native_allgather(x, comm.topo)


@register_impl("allgather", "lane", cost=costs.lane_cost("allgather"))
def _ag_lane(comm, x, *, reorder=True):
    return C.allgather_lane(x, comm.topo, reorder=reorder)


@register_impl("alltoall", "native", cost=costs.native_cost("alltoall"),
               feasible=_div_p)
def _a2a_native(comm, x):
    return C.native_alltoall(x, comm.topo)


@register_impl("alltoall", "lane", cost=costs.lane_cost("alltoall"),
               feasible=_div_p)
def _a2a_lane(comm, x):
    return C.alltoall_lane(x, comm.topo)


@register_impl("moe_route", "native", cost=costs.native_cost("alltoall"),
               feasible=_div_p)
def _moe_route_native(comm, x):
    """Token-routing alltoall (MoE dispatch/combine), one-shot baseline.

    Its own collective name — not an alias of ``alltoall`` — so the tuner
    measures it at MoE routing payloads and ``strategy="auto"`` commits a
    routing-specific choice, but the wire algebra and cost model are the
    §3.5 alltoall's (costs.py delegates the closed forms)."""
    return C.native_alltoall(x, comm.topo)


@register_impl("moe_route", "lane", cost=costs.lane_cost("alltoall"),
               feasible=_div_p)
def _moe_route_lane(comm, x):
    """Decomposed node×lane token-routing alltoall (paper §3.5 applied to
    the expert axis): a2a over nodes on 1/n stripes, then a2a over lanes."""
    return C.alltoall_lane(x, comm.topo)


@register_impl("scan", "native", cost=costs.cost_native_scan)
def _scan_native(comm, x):
    return C.native_scan(x, comm.topo)


@register_impl("scan", "lane", cost=costs.cost_lane_scan, feasible=_div_n)
def _scan_lane(comm, x):
    return C.scan_lane(x, comm.topo)


# ---------------------------------------------------------------------------
# rooted collectives (SPMD masked-root convention, cf. DESIGN.md §2)
# ---------------------------------------------------------------------------

def _is_root(topo, root_lane, root_node):
    return jnp.logical_and(topo.lane_rank() == root_lane,
                           topo.node_rank() == root_node)


@register_impl("bcast", "native", cost=costs.native_cost("bcast"))
def _bcast_native(comm, x, *, root_lane=0, root_node=0,
                  root_replicated=True):
    """One-shot emulation: mask to the root chip, psum the product
    communicator (root replication makes any root-lane replica valid)."""
    topo = comm.topo
    mask = _is_root(topo, root_lane, root_node)
    return lax.psum(jnp.where(mask, x, jnp.zeros_like(x)), _axes(topo))


@register_impl("bcast", "lane", cost=costs.lane_cost("bcast"),
               feasible=_div_n)
def _bcast_lane(comm, x, *, root_lane=0, root_node=0, root_replicated=True):
    return C.bcast_lane(x, comm.topo, root_lane=root_lane,
                        root_node=root_node, root_replicated=root_replicated)


@register_impl("bcast", "lane_pipelined", auto_ok=False, feasible=_div_n)
def _bcast_pipelined(comm, x, *, num_blocks, root_lane=0):
    return pipelined_bcast_lane(x, comm.topo, num_blocks=num_blocks,
                                root_lane=root_lane)


@register_impl("reduce", "native", cost=costs.native_cost("reduce"))
def _reduce_native(comm, x, *, root_lane=0, root_node=0):
    topo = comm.topo
    out = lax.psum(x, _axes(topo))
    return jnp.where(_is_root(topo, root_lane, root_node), out,
                     jnp.zeros_like(out))


@register_impl("reduce", "lane", cost=costs.lane_cost("reduce"),
               feasible=_div_n)
def _reduce_lane(comm, x, *, root_lane=0, root_node=0):
    return C.reduce_lane(x, comm.topo, root_lane=root_lane,
                         root_node=root_node)


@register_impl("reduce", "lane_pipelined", auto_ok=False, feasible=_div_n)
def _reduce_pipelined(comm, x, *, num_blocks, root_lane=0):
    return pipelined_reduce_lane(x, comm.topo, num_blocks=num_blocks,
                                 root_lane=root_lane)


@register_impl("gather", "native", cost=costs.native_cost("gather"))
def _gather_native(comm, x, *, root_lane=0, root_node=0):
    topo = comm.topo
    out = C.native_allgather(x, topo)
    return jnp.where(_is_root(topo, root_lane, root_node), out,
                     jnp.zeros_like(out))


@register_impl("gather", "lane", cost=costs.lane_cost("gather"))
def _gather_lane(comm, x, *, root_lane=0, root_node=0):
    return C.gather_lane(x, comm.topo, root_lane=root_lane,
                         root_node=root_node)


@register_impl("scatter", "native", cost=costs.native_cost("scatter"),
               feasible=_div_p)
def _scatter_native(comm, x, *, root_lane=0, root_node=0,
                    root_replicated=True):
    """Mask-to-root psum broadcast of the whole buffer, then each chip
    slices its global-rank block — the SPMD-emulation volume upper bound
    the cost model charges natives for rooted collectives."""
    topo = comm.topo
    p = topo.p()
    if x.shape[0] % p:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by p={p}")
    m = x.shape[0] // p
    mask = _is_root(topo, root_lane, root_node)
    full = lax.psum(jnp.where(mask, x, jnp.zeros_like(x)), _axes(topo))
    return lax.dynamic_slice_in_dim(full, topo.global_rank() * m, m, axis=0)


@register_impl("scatter", "lane", cost=costs.cost_lane_scatter,
               feasible=_div_p)
def _scatter_lane(comm, x, *, root_lane=0, root_node=0,
                  root_replicated=True):
    return C.scatter_lane(x, comm.topo, root_lane=root_lane,
                          root_node=root_node,
                          root_replicated=root_replicated)


# ---------------------------------------------------------------------------
# grad_sync — the composite training collective (was gradsync.grad_sync's
# if-chain; strategy semantics documented in repro/optim/gradsync.py)
# ---------------------------------------------------------------------------

def _grad_prep(comm, grads, shard_ways: int, num_buckets: int):
    """Shared bucketing prologue: resolve K, flatten+pad to K·shard_ways."""
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(grads))
    K = resolve_num_buckets(total, shard_ways, num_buckets)
    flat, spec = _flatten_bucket(grads, pad_to=K * shard_ways)
    return K, flat, spec


@register_impl("grad_sync", "native", cost=costs.native_cost("allreduce"))
def _gs_native(comm, grads, *, num_buckets=0):
    topo = comm.topo
    nrep = _nrep(topo)
    return jax.tree.map(lambda g: lax.psum(g, _axes(topo)) / nrep, grads)


@register_impl("grad_sync", "lane", cost=costs.lane_cost("allreduce"))
def _gs_lane(comm, grads, *, num_buckets=0):
    topo = comm.topo
    K, flat, spec = _grad_prep(comm, grads, topo.n(), num_buckets)
    parts = bucket_schedule(
        flat, K, (_rs_node(topo), _ar_lane(topo), _ag_node(topo)))
    return _unflatten_bucket(jnp.concatenate(parts) / _nrep(topo), spec)


@register_impl("grad_sync", "lane_pipelined",
               cost=costs.cost_pipelined_allreduce)
def _gs_pipelined(comm, grads, *, num_buckets=0):
    topo = comm.topo
    K, flat, spec = _grad_prep(comm, grads, topo.n(), num_buckets)
    out = _pipelined_allreduce_lane(flat, topo, num_blocks=K) / _nrep(topo)
    return _unflatten_bucket(out, spec)


@register_impl("grad_sync", "lane_quorum", auto_ok=False, feasible=_div_n)
def _gs_quorum(comm, grads, *, num_buckets=0, contributing=None):
    """Quorum-degraded lane sync: the DCN hop becomes a masked mean.

    Identical bucket schedule to ``lane`` — RS(node) → AR(lane) →
    AG(node) — but the lane allreduce is ``runtime.straggler``'s quorum
    stage: THIS pod's ``contributing`` bit (0/1 scalar, from the
    host-side watchdog) zeroes its payload and the divisor is the live
    pod count instead of the lane size, so a masked pod's gradient
    provably cannot influence the result — the step equals the same
    step with that pod's microbatch skipped, which the (seed, step)-
    keyed data pipeline can replay.  ``contributing=None`` means a full
    quorum (all ones), bit-identical to ``lane`` on power-of-two pod
    counts.  Never auto-selected: the result is a DIFFERENT estimator
    (fewer samples) whenever any pod is masked.
    """
    from repro.runtime.straggler import quorum_stage
    topo = comm.topo
    if contributing is None:
        contributing = jnp.ones((), jnp.float32)
    K, flat, spec = _grad_prep(comm, grads, topo.n(), num_buckets)
    parts = bucket_schedule(
        flat, K, (_rs_node(topo),
                  quorum_stage(topo.lane_axis, contributing),
                  _ag_node(topo)))
    # the quorum stage already divided by the live lane count; only the
    # node-level replication factor is left
    return _unflatten_bucket(jnp.concatenate(parts) / topo.n(), spec)


@register_impl("grad_sync", "lane_int8", auto_ok=False)
def _gs_int8(comm, grads, *, num_buckets=0):
    """Lossy (int8 DCN hop): opt-in only, never auto-selected."""
    topo = comm.topo
    K, flat, spec = _grad_prep(comm, grads, topo.n(), num_buckets)
    parts = bucket_schedule(
        flat, K, (_rs_node(topo), _ar_lane_int8(topo), _ag_node(topo)))
    return _unflatten_bucket(jnp.concatenate(parts) / _nrep(topo), spec)


@register_impl("grad_sync", "lane_zero1", auto_ok=False)
def _gs_zero1(comm, grads, *, num_buckets=0):
    """Returns (node-sharded flat, spec): the caller owns the deferred
    all-gather (moved past the optimizer — see launch/steps.py)."""
    topo = comm.topo
    nrep = _nrep(topo)
    K, flat, spec = _grad_prep(comm, grads, topo.n(), num_buckets)
    parts = bucket_schedule(
        flat, K,
        (_rs_node(topo), lambda v: lax.psum(v, topo.lane_axis) / nrep))
    return jnp.concatenate(parts), spec


@register_impl("grad_sync", "lane_zero3", auto_ok=False)
def _gs_zero3(comm, grads, *, num_buckets=0):
    """Returns (1/p-sharded flat, spec): full RS over BOTH levels; the
    layer prefetch re-gathers during the next forward (launch/steps.py)."""
    topo = comm.topo
    nrep = _nrep(topo)
    K, flat, spec = _grad_prep(comm, grads, topo.n() * topo.N(), num_buckets)
    parts = bucket_schedule(
        flat, K,
        (_rs_node(topo), lambda v: lax.psum_scatter(
            v, topo.lane_axis, scatter_dimension=0, tiled=True) / nrep))
    return jnp.concatenate(parts), spec


# ---------------------------------------------------------------------------
# prefetch_allgather — the ZeRO-3 per-layer weight re-gather
# ---------------------------------------------------------------------------

def _resolve_blocks(comm, lead: int, num_blocks) -> int:
    """B for a per-chip stripe of ``lead`` fp32 rows.

    An EXPLICIT num_blocks is strict: it names a shard layout the caller
    already committed to, so an indivisible value must raise downstream
    (silently shrinking it would reassemble blocks against the wrong
    layout — permuted weights).  Only the auto path (None) may shrink:
    cfg.prefetch_blocks (-1 → 1, the blocking control) or the cost model
    on the stripe bytes, clamped to a divisor of lead."""
    if num_blocks is not None:
        return num_blocks
    ov = comm.cfg.prefetch_blocks
    if ov > 0:
        B = ov
    elif ov < 0:
        B = 1
    else:
        B = optimal_prefetch_blocks(lead * 4)
    B = max(1, min(B, lead))
    while lead % B:
        B -= 1
    return B


@register_impl("prefetch_allgather", "lane_pipelined",
               cost=costs.cost_pipelined_allgather)
def _prefetch_pipelined(comm, shard, *, num_blocks=None):
    B = _resolve_blocks(comm, shard.shape[0], num_blocks)
    return pipelined_allgather_lane(shard, comm.topo, num_blocks=B)


@register_impl("prefetch_allgather", "blocking", auto_ok=False,
               probe_ok=True)
def _prefetch_blocking(comm, shard, *, num_blocks=None):
    """Monolithic AG(lane)→AG(node) of the whole shard — the comparator
    and the negative control of the prefetch-overlap HLO proof.
    ``probe_ok=True``: never auto-selected, but the probe sweep times it
    so the measured pipelined-vs-blocking gap lands in the cache."""
    B = _resolve_blocks(comm, shard.shape[0], num_blocks)
    return zero3_unshard(shard, comm.topo, B)


# ---------------------------------------------------------------------------
# kv_splice — the serving-side KV/state distribution collective
# ---------------------------------------------------------------------------
#
# Continuous batching with slots sharded over the mesh needs exactly one
# communication primitive: after a batch-1 prefill (computed replicated —
# every chip runs it, the root's copy is canonical), the fresh cache leaf
# must land in slot `slot` of the batch-sharded cache, which lives on
# exactly one chip.  That is a rooted broadcast of the leaf followed by a
# purely local splice — the paper's decomposed bcast applied to the KV
# payload.  Slot ownership follows the same global-rank block order as
# `scatter`: chip r owns slots [r·B_local, (r+1)·B_local).

def _splice_local(comm, big, small, slot, batch_axis: int):
    """Local half of kv_splice: write `small` (batch-1 along batch_axis)
    into global slot `slot` of this chip's local slot-shard `big`, or
    leave `big` untouched when the slot lives on another chip.  `slot`
    may be traced (the engine jits the splice per slot array)."""
    B_local = big.shape[batch_axis]
    local = jnp.asarray(slot, jnp.int32) - comm.topo.global_rank() * B_local
    inb = jnp.logical_and(local >= 0, local < B_local)
    upd = lax.dynamic_update_slice_in_dim(
        big, small.astype(big.dtype), jnp.clip(local, 0, B_local - 1),
        axis=batch_axis)
    return jnp.where(inb, upd, big)


@register_impl("kv_splice", "native", auto_ok=False)
def _kv_splice_native(comm, big, *, small, slot, batch_axis=1,
                      root_lane=0, root_node=0):
    """One-shot baseline: mask-to-root psum of the whole leaf (the same
    SPMD emulation `bcast/native` charges), then the local splice."""
    topo = comm.topo
    mask = _is_root(topo, root_lane, root_node)
    small = lax.psum(jnp.where(mask, small, jnp.zeros_like(small)),
                     _axes(topo))
    return _splice_local(comm, big, small, slot, batch_axis)


@register_impl("kv_splice", "lane", auto_ok=False)
def _kv_splice_lane(comm, big, *, small, slot, batch_axis=1,
                    root_lane=0, root_node=0):
    """Decomposed variant: the leaf is flattened, zero-padded to a
    multiple of n, and broadcast through the §3 lane bcast (scatter on
    the root's lane + allgather per lane + bcast down the nodes), then
    spliced locally — multi-lane bandwidth on the KV distribution hop."""
    topo = comm.topo
    n = topo.n()
    flat = small.reshape(-1)
    pad = (-flat.shape[0]) % max(n, 1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    out = C.bcast_lane(flat, topo, root_lane=root_lane,
                       root_node=root_node, root_replicated=True)
    small = out[:small.size].reshape(small.shape)
    return _splice_local(comm, big, small, slot, batch_axis)
