"""Cost functions for auto-dispatch — the §3/§5 model per registration.

Every function has the registry's cost signature ``(n, N, payload_bytes,
cfg) -> seconds`` with n = processes per node (intra-pod chips), N =
nodes (pods).  Two modelling conventions (DESIGN.md §6):

* **native** — the "native library" baseline is charged the collective's
  optimal per-process volume at the *slowest level present* (DCN when
  N > 1, else ICI) with NO lane concurrency: the paper's premise is that
  native libraries do not exploit multi-lane communication, so the whole
  payload crosses a single lane.  Rounds: log₂ p at that level's alpha.
* **lane** — ``klane_time`` over ``mockup_cost``: node phases at ICI
  alpha/beta, lane phases at DCN alpha/beta with the full-lane 1/n
  payload split already folded into the §3 volumes.
* **lane_pipelined** — ``bucket_pipeline_time`` on the per-lane DCN
  stripe with the bucket count the dispatcher would actually run
  (cfg.buckets, 0 = the K* crossover): (K+S-1) waves of one DCN alpha
  plus the per-bucket bandwidth term; the ICI stages ride under it once
  the pipeline is full — the §5 simultaneity assumption.

All costs are deterministic in their inputs, so the auto choice is
reproducible and the recorded Selection can be asserted in tests.
"""
from __future__ import annotations

from repro.core.costmodel import (
    _lg, bucket_pipeline_time, get_hw, klane_time, mockup_cost,
    optimal_num_buckets,
)
from repro.core.pipeline import ALLGATHER_STAGES, ALLREDUCE_STAGES

__all__ = [
    "native_cost", "lane_cost", "cost_pipelined_allreduce",
    "cost_pipelined_allgather", "cost_native_scan", "cost_lane_scan",
    "cost_lane_scatter", "lowered_wire_volumes", "assumed_volumes",
]

_ROUND_FACTOR = {  # rounds multiplier: reduce+broadcast shapes pay 2 phases
    "allreduce": 2, "reduce": 2, "bcast": 2,
}


def _level(N: int) -> tuple[float, float]:
    """(alpha, beta) of the slowest level present: DCN iff multi-node.

    Reads the ACTIVE constants (core.costmodel.get_hw) at call time, so
    a fitted HW installed by the tuning subsystem reprices every ranking
    without re-registering a single cost function.
    """
    hw = get_hw()
    if N > 1:
        return hw.alpha_dcn, 1.0 / hw.dcn_bw
    return hw.alpha_ici, 1.0 / hw.ici_bw


def native_cost(coll: str):
    """Single-lane native baseline for one §3 collective."""
    def cost(n: int, N: int, c_bytes: float, cfg) -> float:
        p = max(n * N, 1)
        alpha, beta = _level(N)
        rounds = _ROUND_FACTOR.get(coll, 1) * _lg(p)
        return rounds * alpha + mockup_cost(coll, n, N, c_bytes).optimal_vol \
            * beta
    return cost


def lane_cost(coll: str):
    """Full-lane mock-up under the k-lane model (paper §5)."""
    def cost(n: int, N: int, c_bytes: float, cfg) -> float:
        hw = get_hw()
        return klane_time(
            mockup_cost(coll, n, N, c_bytes), k=n, elem_bytes=1,
            alpha_node=hw.alpha_ici, beta_node=1.0 / hw.ici_bw,
            alpha_lane=hw.alpha_dcn, beta_lane=1.0 / hw.dcn_bw)
    return cost


def cost_pipelined_allreduce(n: int, N: int, c_bytes: float, cfg) -> float:
    """§5 pipelined allreduce: K buckets × 3 stages on the bottleneck
    stripe (DCN when multi-node, else the ICI ring is the bottleneck)."""
    alpha, beta = _level(N)
    stripe = c_bytes / max(n, 1)
    K = cfg.buckets if cfg.buckets > 0 \
        else optimal_num_buckets(stripe, alpha=alpha, beta=beta)
    return bucket_pipeline_time(stripe, max(K, 1), stages=ALLREDUCE_STAGES,
                                alpha=alpha, beta=beta)


def cost_pipelined_allgather(n: int, N: int, c_bytes: float, cfg) -> float:
    """§5 pipelined allgather (ZeRO-3 prefetch): B blocks × 2 stages.

    ``c_bytes`` is the per-chip 1/p shard — the bytes the DCN hop moves.
    """
    alpha, beta = _level(N)
    B = cfg.prefetch_blocks if cfg.prefetch_blocks > 0 \
        else optimal_num_buckets(c_bytes, stages=ALLGATHER_STAGES,
                                 alpha=alpha, beta=beta, max_buckets=16)
    return bucket_pipeline_time(c_bytes, max(B, 1), stages=ALLGATHER_STAGES,
                                alpha=alpha, beta=beta)


# -- scan has no mockup_cost entry (the paper lists it without a §3
#    analysis); charge the emulation's actual all-gather volumes ---------

def cost_native_scan(n: int, N: int, c_bytes: float, cfg) -> float:
    """Direct algorithm: gather the whole communicator, (p-1)·c moved."""
    p = max(n * N, 1)
    alpha, beta = _level(N)
    return _lg(p) * alpha + (p - 1) * c_bytes * beta


def cost_lane_scan(n: int, N: int, c_bytes: float, cfg) -> float:
    """Scan(node) + striped Exscan(lane) + AG(node) emulation volumes.

    The lane phase is an UNTILED all-gather of the c/n stripe (every chip
    keeps all N partial stripes to form its exclusive prefix), so it
    moves (N-1)·c/n — not the tiled (N-1)/N·c/n.  lanelint R3 pinned the
    earlier tiled charge as an undercount against the lowered HLO.
    """
    hw = get_hw()
    t_node = 2 * _lg(n) * hw.alpha_ici \
        + 2 * (n - 1) * c_bytes / hw.ici_bw          # node scan + final AG
    t_lane = _lg(N) * hw.alpha_dcn \
        + (N - 1) * (c_bytes / max(n, 1)) / hw.dcn_bw
    return t_node + t_lane


def cost_lane_scatter(n: int, N: int, c_bytes: float, cfg) -> float:
    """Root-replicated lane scatter: every node already holds the full
    buffer, so the ONLY communication is the tiled lane all-to-all on the
    local c/n stripe — there is no node phase to charge.  (The generic
    ``lane_cost("scatter")`` mock-up prices a node scatter phase that the
    root-replicated lowering never emits; lanelint R3 flags that as
    pricing a phase that does not exist.)"""
    hw = get_hw()
    stripe = c_bytes / max(n, 1)
    return _lg(N) * hw.alpha_dcn \
        + (N - 1) / max(N, 1) * stripe / hw.dcn_bw


# ---------------------------------------------------------------------------
# lanelint predicates: what the lowerings MOVE and what the costs CHARGE
# ---------------------------------------------------------------------------
#
# ``lowered_wire_volumes`` is the exact per-level wire algebra of each
# registered cell's HLO (per-op convention of
# repro.analysis.footprint._footprint_wire: all-reduce 2(g-1)/g·result,
# tiled all-gather (g-1)/g·result, reduce-scatter (g-1)·shard,
# all-to-all (g-1)/g·buffer, collective-permute = payload).  lanelint R2
# errors when the compiled HLO disagrees — payload is being duplicated
# or dropped somewhere in the decomposition.
#
# ``assumed_volumes`` is what the matching COST function charges, plus
# the cell's documented consistency bound.  lanelint R3 errors when cost
# and lowering diverge beyond the bound — the §2 self-consistency
# requirement ("guidelines must describe the implementation they rank").

#: base R3 bound: a cost model within 4× of its lowering still ranks the
#: native/lane/pipelined alternatives in the regimes the paper needs
_R3_BASE_BOUND = 4.0


def lowered_wire_volumes(collective: str, strategy: str, *, n: int,
                         N: int, payload_bytes: float,
                         num_blocks=None, num_buckets=None):
    """Exact per-level wire bytes {level: bytes} one execution of the
    cell moves, or None when the cell has no closed form registered
    (unknown cells are a lint error upstream, not silently passed)."""
    c = float(payload_bytes)
    p = max(n * N, 1)
    B = num_blocks or 1
    K = num_buckets or 1
    if collective == "moe_route":
        # moe_route's registered impls ARE the §3.5 alltoall lowerings
        # (C.native_alltoall / C.alltoall_lane) — identical HLO, so the
        # token-routing cells share the dump-verified alltoall algebra
        collective = "alltoall"
    key = (collective, strategy)

    if key == ("allreduce", "native") or key == ("grad_sync", "native"):
        return {"global": 2 * (p - 1) / p * c}
    if key == ("allreduce", "lane") or key == ("grad_sync", "lane") \
            or key == ("reduce", "lane"):
        return {"node": 2 * (n - 1) / n * c,
                "lane": 2 * (N - 1) / N * c / n}
    if key == ("allreduce", "lane_pipelined") \
            or key == ("grad_sync", "lane_pipelined"):
        # T = B+2 scan steps, each: RS(node, block) + ring-AR(lane,
        # stripe) + AG(node, block); warmup/drain steps run on garbage
        T = (B if collective == "allreduce" else K) + 2
        KB = B if collective == "allreduce" else K
        return {"node": 2 * (n - 1) / n * c * T / KB,
                "lane": (N - 1) * (c / n) * T / KB}
    if key == ("grad_sync", "lane_quorum"):
        # lane strategy + one scalar denominator psum per bucket (rides
        # inside lanelint's absolute tolerance)
        return {"node": 2 * (n - 1) / n * c,
                "lane": 2 * (N - 1) / N * c / n}
    if key == ("grad_sync", "lane_zero1"):
        # RS(node) + full lane psum of the stripe; no node AG (shards
        # stay resident for the sharded optimizer)
        return {"node": (n - 1) / n * c,
                "lane": 2 * (N - 1) / N * c / n}
    if key == ("grad_sync", "lane_zero3"):
        # RS(node) + psum_scatter(lane) of the stripe → 1/p shard out
        return {"node": (n - 1) / n * c,
                "lane": (N - 1) * c / p}
    if key == ("grad_sync", "lane_int8"):
        # RS(node) + packed-int8 untiled lane AG + AG(node) of the
        # dequantized stripe.  The compressor pads each bucket stripe up
        # to whole 1024-element chunks (1024 int8 B + one f32 scale per
        # chunk → 1028 B/chunk on the wire).
        import math
        elems_b = c / 4 / K / n
        chunks = max(1, math.ceil(elems_b / 1024))
        return {"node": 2 * (n - 1) / n * c,
                "lane": (N - 1) * K * 1028 * chunks}
    if key == ("reduce_scatter", "native"):
        return {"lane": (N - 1) / N * c, "node": (n - 1) * c / p}
    if key == ("reduce_scatter", "lane"):
        return {"node": (n - 1) / n * c, "lane": (N - 1) * c / p}
    if key == ("allgather", "native") or key == ("scan", "native") \
            or key == ("gather", "native"):
        return {"node": (n - 1) * c, "lane": (N - 1) * n * c}
    if key == ("allgather", "lane") or key == ("gather", "lane"):
        return {"lane": (N - 1) * c, "node": (n - 1) * N * c}
    if key == ("alltoall", "native") or key == ("alltoall", "lane"):
        return {"lane": (N - 1) / N * c, "node": (n - 1) / n * c}
    if key == ("scan", "lane"):
        # AG(node, full) for the node scan + untiled lane AG of the c/n
        # stripe + AG(node) of the stripe for the broadcast-back
        return {"node": (n - 1) * c + (n - 1) / n * c,
                "lane": (N - 1) * c / n}
    if key in (("bcast", "native"), ("reduce", "native"),
               ("scatter", "native")):
        return {"global": 2 * (p - 1) / p * c}   # masked-psum emulation
    if key == ("bcast", "lane"):
        return {"node": (n - 1) / n * c,
                "lane": 2 * (N - 1) / N * c / n}
    if key == ("bcast", "lane_pipelined"):
        # T = B+N-1 ring steps, each: ppermute(lane, s) + untiled
        # AG(node) assembling the block from its s = c/(B·n) stripes
        T = B + N - 1
        s = c / (B * n)
        return {"lane": T * s, "node": (n - 1) * T * s}
    if key == ("reduce", "lane_pipelined"):
        # dual ring: per step RS(node, block) + ppermute(lane, s), then
        # ONE trailing tiled AG(node) reassembling the root lane's c
        T = B + N - 1
        s = c / (B * n)
        return {"lane": T * s,
                "node": (n - 1) * T * s + (n - 1) / n * c}
    if key == ("scatter", "lane"):
        # root-replicated: local pick + tiled lane a2a on the stripe
        return {"lane": (N - 1) / N * c / n}
    if key in (("prefetch_allgather", "lane_pipelined"),
               ("prefetch_allgather", "blocking")):
        # totals match the monolithic unshard: tiled lane AG of the
        # shard, then node AG of the lane-complete buffer
        return {"lane": (N - 1) * c, "node": (n - 1) * N * c}
    if key == ("kv_splice", "native"):
        return {"global": 2 * (p - 1) / p * c}
    if key == ("kv_splice", "lane"):
        # bcast/lane on the flattened small payload padded to n | elems
        import math
        elems = c / 4
        pad = math.ceil(elems / n) * n * 4
        return {"node": (n - 1) / n * pad,
                "lane": 2 * (N - 1) / N * pad / n}
    return None


def assumed_volumes(collective: str, strategy: str, *, n: int, N: int,
                    payload_bytes: float, num_blocks=None,
                    num_buckets=None):
    """({level-or-"total": bytes}, bound) the registered cost function
    charges, or None when the cell carries no cost (auto_ok=False cells
    are dispatched explicitly; there is no ranking to keep honest).

    "total" compares against the SUM of lowered levels — native costs
    charge a single slowest-level volume while their lowering may be
    level-pure.  The bound widens only for documented convention gaps:

    * alltoall (both) and scatter/native use the §3 per-destination-block
      convention (mock-up ``c`` = one block) while dispatch passes the
      whole local buffer → ratio p by construction.
    * pipelined cells charge only the bottleneck DCN stripe; the ICI
      stages ride under it (§5 simultaneity), and the lane ring moves
      (N-1)× the stripe the bucket model prices → ratio up to N-1.
    """
    c = float(payload_bytes)
    p = max(n * N, 1)
    if collective == "moe_route":
        # same delegation as lowered_wire_volumes: the cost functions
        # registered on the moe_route cells are the alltoall ones
        collective = "alltoall"
    key = (collective, strategy)
    no_cost = {
        ("bcast", "lane_pipelined"), ("reduce", "lane_pipelined"),
        ("grad_sync", "lane_quorum"), ("grad_sync", "lane_int8"),
        ("grad_sync", "lane_zero1"), ("grad_sync", "lane_zero3"),
        ("prefetch_allgather", "blocking"),
        ("kv_splice", "native"), ("kv_splice", "lane"),
    }
    if key in no_cost:
        return None

    if strategy == "native" and collective != "scan":
        coll = "allreduce" if collective == "grad_sync" else collective
        vol = mockup_cost(coll, n, N, c).optimal_vol
        bound = _R3_BASE_BOUND
        if collective in ("alltoall", "scatter"):
            bound *= p                       # per-destination-block gap
        return {"total": vol}, bound
    if strategy == "lane" and collective not in ("scan", "scatter"):
        coll = "allreduce" if collective == "grad_sync" else collective
        mc = mockup_cost(coll, n, N, c)
        bound = _R3_BASE_BOUND * (p if collective == "alltoall" else 1)
        return {"node": mc.vol_node, "lane": mc.vol_lane}, bound
    if key == ("scan", "native"):
        return {"total": (p - 1) * c}, _R3_BASE_BOUND
    if key == ("scan", "lane"):
        return {"node": 2 * (n - 1) * c,
                "lane": (N - 1) * c / n}, _R3_BASE_BOUND
    if key == ("scatter", "lane"):
        return {"lane": (N - 1) / N * (c / n)}, _R3_BASE_BOUND
    if key in (("allreduce", "lane_pipelined"),
               ("grad_sync", "lane_pipelined")):
        # bucket model charges ≈ the c/n stripe once on DCN; the ring
        # lowering moves (N-1)× that and the ICI stages ride under
        return {"lane": c / n}, _R3_BASE_BOUND * max(N - 1, 1)
    if key == ("prefetch_allgather", "lane_pipelined"):
        return {"lane": c}, _R3_BASE_BOUND * max(N - 1, 1)
    return None
