"""Cost functions for auto-dispatch — the §3/§5 model per registration.

Every function has the registry's cost signature ``(n, N, payload_bytes,
cfg) -> seconds`` with n = processes per node (intra-pod chips), N =
nodes (pods).  Two modelling conventions (DESIGN.md §6):

* **native** — the "native library" baseline is charged the collective's
  optimal per-process volume at the *slowest level present* (DCN when
  N > 1, else ICI) with NO lane concurrency: the paper's premise is that
  native libraries do not exploit multi-lane communication, so the whole
  payload crosses a single lane.  Rounds: log₂ p at that level's alpha.
* **lane** — ``klane_time`` over ``mockup_cost``: node phases at ICI
  alpha/beta, lane phases at DCN alpha/beta with the full-lane 1/n
  payload split already folded into the §3 volumes.
* **lane_pipelined** — ``bucket_pipeline_time`` on the per-lane DCN
  stripe with the bucket count the dispatcher would actually run
  (cfg.buckets, 0 = the K* crossover): (K+S-1) waves of one DCN alpha
  plus the per-bucket bandwidth term; the ICI stages ride under it once
  the pipeline is full — the §5 simultaneity assumption.

All costs are deterministic in their inputs, so the auto choice is
reproducible and the recorded Selection can be asserted in tests.
"""
from __future__ import annotations

from repro.core.costmodel import (
    _lg, bucket_pipeline_time, get_hw, klane_time, mockup_cost,
    optimal_num_buckets,
)
from repro.core.pipeline import ALLGATHER_STAGES, ALLREDUCE_STAGES

__all__ = [
    "native_cost", "lane_cost", "cost_pipelined_allreduce",
    "cost_pipelined_allgather", "cost_native_scan", "cost_lane_scan",
]

_ROUND_FACTOR = {  # rounds multiplier: reduce+broadcast shapes pay 2 phases
    "allreduce": 2, "reduce": 2, "bcast": 2,
}


def _level(N: int) -> tuple[float, float]:
    """(alpha, beta) of the slowest level present: DCN iff multi-node.

    Reads the ACTIVE constants (core.costmodel.get_hw) at call time, so
    a fitted HW installed by the tuning subsystem reprices every ranking
    without re-registering a single cost function.
    """
    hw = get_hw()
    if N > 1:
        return hw.alpha_dcn, 1.0 / hw.dcn_bw
    return hw.alpha_ici, 1.0 / hw.ici_bw


def native_cost(coll: str):
    """Single-lane native baseline for one §3 collective."""
    def cost(n: int, N: int, c_bytes: float, cfg) -> float:
        p = max(n * N, 1)
        alpha, beta = _level(N)
        rounds = _ROUND_FACTOR.get(coll, 1) * _lg(p)
        return rounds * alpha + mockup_cost(coll, n, N, c_bytes).optimal_vol \
            * beta
    return cost


def lane_cost(coll: str):
    """Full-lane mock-up under the k-lane model (paper §5)."""
    def cost(n: int, N: int, c_bytes: float, cfg) -> float:
        hw = get_hw()
        return klane_time(
            mockup_cost(coll, n, N, c_bytes), k=n, elem_bytes=1,
            alpha_node=hw.alpha_ici, beta_node=1.0 / hw.ici_bw,
            alpha_lane=hw.alpha_dcn, beta_lane=1.0 / hw.dcn_bw)
    return cost


def cost_pipelined_allreduce(n: int, N: int, c_bytes: float, cfg) -> float:
    """§5 pipelined allreduce: K buckets × 3 stages on the bottleneck
    stripe (DCN when multi-node, else the ICI ring is the bottleneck)."""
    alpha, beta = _level(N)
    stripe = c_bytes / max(n, 1)
    K = cfg.buckets if cfg.buckets > 0 \
        else optimal_num_buckets(stripe, alpha=alpha, beta=beta)
    return bucket_pipeline_time(stripe, max(K, 1), stages=ALLREDUCE_STAGES,
                                alpha=alpha, beta=beta)


def cost_pipelined_allgather(n: int, N: int, c_bytes: float, cfg) -> float:
    """§5 pipelined allgather (ZeRO-3 prefetch): B blocks × 2 stages.

    ``c_bytes`` is the per-chip 1/p shard — the bytes the DCN hop moves.
    """
    alpha, beta = _level(N)
    B = cfg.prefetch_blocks if cfg.prefetch_blocks > 0 \
        else optimal_num_buckets(c_bytes, stages=ALLGATHER_STAGES,
                                 alpha=alpha, beta=beta, max_buckets=16)
    return bucket_pipeline_time(c_bytes, max(B, 1), stages=ALLGATHER_STAGES,
                                alpha=alpha, beta=beta)


# -- scan has no mockup_cost entry (the paper lists it without a §3
#    analysis); charge the emulation's actual all-gather volumes ---------

def cost_native_scan(n: int, N: int, c_bytes: float, cfg) -> float:
    """Direct algorithm: gather the whole communicator, (p-1)·c moved."""
    p = max(n * N, 1)
    alpha, beta = _level(N)
    return _lg(p) * alpha + (p - 1) * c_bytes * beta


def cost_lane_scan(n: int, N: int, c_bytes: float, cfg) -> float:
    """Scan(node) + striped Exscan(lane) + AG(node) emulation volumes."""
    hw = get_hw()
    t_node = 2 * _lg(n) * hw.alpha_ici \
        + 2 * (n - 1) * c_bytes / hw.ici_bw          # node scan + final AG
    t_lane = _lg(N) * hw.alpha_dcn \
        + (N - 1) / max(N, 1) * (c_bytes / max(n, 1)) / hw.dcn_bw
    return t_node + t_lane
