"""repro.comm — communicator-object collective API (the paper's §2 made
first-class).

One :class:`LaneComm` object = one decomposed communication domain
(:class:`~repro.core.lane.LaneTopology`) + one typed tuning surface
(:class:`CommConfig`), exposing the full collective surface through a
decorator-based implementation registry with cost-model auto-dispatch::

    comm = LaneComm(topo, CommConfig.from_run(run))
    grads = comm.grad_sync(grads)                  # cfg-default strategy
    out = comm.allreduce(x, strategy="auto")       # cost-model pick,
    comm.last_selection                            #   recorded here

Registering a new implementation is one decorator (see
:mod:`repro.comm.impls`); consumers, error messages, benchmarks and the
CI schema check all derive their strategy lists from the registry, so a
registration is self-documenting.  The public surface below is locked by
tests/test_api_surface.py.
"""
from .config import CommConfig
from .lanecomm import LaneComm, Selection
from .layout import param_layout_kind, register_param_layout
from .registry import (
    ImplEntry, get_impl, has_impl, iter_impls, register_impl,
    registered_collectives, strategies_for,
)
from . import impls as _impls  # populate the registry  # noqa: F401

__all__ = [
    "LaneComm", "CommConfig", "Selection",
    "ImplEntry", "register_impl", "get_impl", "has_impl", "iter_impls",
    "strategies_for", "registered_collectives",
    "register_param_layout", "param_layout_kind",
]
