"""LaneComm — the MPI-style communicator object over a LaneTopology.

The paper's core abstraction is the decomposition of the communication
domain into node and lane communicators (§2, Listings 1–6).  ``LaneComm``
makes that abstraction first-class: one object carries the factorization
(:class:`~repro.core.lane.LaneTopology`), the tuning surface
(:class:`~repro.comm.config.CommConfig`) and the full collective surface
— ``allreduce``/``reduce_scatter``/``allgather``/``bcast``/``alltoall``/
``reduce``/``gather``/``scatter``/``scan`` plus the composite training
collectives ``grad_sync`` and ``prefetch_allgather``.  Every method
resolves through the implementation registry
(:mod:`~repro.comm.registry`); ``strategy="auto"`` ranks the registered
implementations with the §3/§5 cost model and records the choice so the
HLO structural checkers (and benchmarks) can assert what actually ran.

Collective methods must be called inside ``jax.shard_map`` with the
topology's axes manual, exactly like the underlying mock-ups; auto
ranking resolves n/N at trace time (or from ``mesh`` when given, for
out-of-shard_map queries like :meth:`LaneComm.select`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax

from repro.core.lane import LaneTopology

from .config import CommConfig
from .registry import get_impl, has_impl, iter_impls, strategies_for

__all__ = ["LaneComm", "Selection"]


@dataclasses.dataclass(frozen=True)
class Selection:
    """One recorded auto-dispatch decision (trace-time).

    ranking: ((seconds, strategy), ...) — the full cost table the choice
    was made from, for benchmarks and failure messages.  Ascending in
    seconds; with a tuner attached, measured cells sort ahead of
    modelled ones (so seconds are ascending only within each tier).
    source: where the winning cost came from — ``"measured"`` (timing
    cache, ``cfg.tuner``) or ``"model"`` (the §3/§5 closed form).
    """
    collective: str
    strategy: str
    payload_bytes: int
    ranking: tuple
    source: str = "model"


def _payload_bytes(x: Any) -> int:
    """Wire-relevant payload size: leaves' byte sizes summed (grad_sync
    flattens to fp32, so trees are charged at 4 B/element)."""
    leaves = jax.tree.leaves(x)
    if len(leaves) == 1 and hasattr(leaves[0], "dtype"):
        l = leaves[0]
        return math.prod(l.shape) * l.dtype.itemsize
    return sum(math.prod(l.shape) for l in leaves) * 4


def _lead(x: Any) -> Optional[int]:
    """Leading dim for feasibility checks; None for trees (impls pad)."""
    leaves = jax.tree.leaves(x)
    if len(leaves) == 1 and getattr(leaves[0], "ndim", 0) >= 1:
        return leaves[0].shape[0]
    return None


class LaneComm:
    """The (node × lane) communicator object (see module docstring).

    mesh: optional concrete Mesh for resolving n/N outside shard_map
    (auto ranking inside shard_map reads trace-time axis sizes instead).
    selections: Selection records of every auto dispatch, in call order —
    trace-time Python state, so lower/compile once and then inspect.
    """

    def __init__(self, topo: LaneTopology, cfg: Optional[CommConfig] = None,
                 *, mesh=None):
        self.topo = topo
        self.cfg = cfg if cfg is not None else CommConfig()
        self.mesh = mesh
        self.selections: list[Selection] = []
        self._select_source = "model"   # source of the last select() win

    # -- sizes -----------------------------------------------------------
    def sizes(self) -> tuple[int, int]:
        """(n, N): trace-time axis sizes, or read off ``mesh`` outside."""
        try:
            return self.topo.n(), self.topo.N()
        except Exception:
            if self.mesh is not None:
                return self.topo.sizes(self.mesh)
            raise

    # -- auto-dispatch ---------------------------------------------------
    def select(self, collective: str, payload_bytes: int, *,
               n: Optional[int] = None, N: Optional[int] = None,
               lead: Optional[int] = None) -> tuple[str, tuple]:
        """Rank auto-eligible registrations by measured-then-modelled cost.

        Returns (winning strategy, ((seconds, strategy), ...)).  Entries
        are skipped when they are lossy/layout-changing
        (``auto_ok=False``), have no cost model, or fail their
        divisibility precondition for ``lead``.

        Without a tuner (``cfg.tuner is None``) every cell is priced by
        the §3/§5 closed form and the ranking is ascending in seconds.
        With a tuner, each cell is first looked up in the measured
        timing table; MEASURED cells rank ahead of modelled ones (a
        measured 394 µs must beat a modelled 68 µs fiction — the
        BENCH_gradsync mispredict this subsystem exists to fix), and
        unmeasured cells keep their closed-form fallback.  The source of
        the winning cost lands on the recorded ``Selection.source``.
        """
        if n is None or N is None:
            n, N = self.sizes()
        tuner = self.cfg.tuner
        table = []
        for e in iter_impls(collective):
            if not e.auto_ok or e.cost is None:
                continue
            if lead is not None and e.feasible is not None \
                    and not e.feasible(n, N, lead):
                continue
            measured = None if tuner is None else tuner.measured_cost(
                collective, e.strategy, n, N, payload_bytes)
            if measured is not None:
                table.append((0, float(measured), e.strategy))
            else:
                table.append((1, float(e.cost(n, N, payload_bytes,
                                              self.cfg)), e.strategy))
        if not table:
            raise ValueError(
                f"no auto-dispatchable implementation for {collective!r} "
                f"(payload {payload_bytes} B, n={n}, N={N}); registered "
                f"strategies: {strategies_for(collective)}")
        table.sort()
        self._select_source = "measured" if table[0][0] == 0 else "model"
        ranking = tuple((t, s) for _, t, s in table)
        return ranking[0][1], ranking

    @property
    def last_selection(self) -> Optional[Selection]:
        return self.selections[-1] if self.selections else None

    # -- parameter layout ------------------------------------------------
    def param_layout(self, strategy: Optional[str] = None) -> str:
        """Master-parameter layout kind the registered train step for
        ``strategy`` (default: ``cfg.strategy``) expects on THIS topology:
        ``"replicated"`` | ``"zero1"`` | ``"zero3"``.

        Mirrors the step builders' single-batch-axis degradation: with an
        empty node level (no distinct intra-node axes) ZeRO-1 falls back
        to the replicated native step, so its layout answer degrades the
        same way.  Drivers and the checkpoint store key their state init
        and shard specs off this answer instead of hard-coding a strategy
        → layout mapping (see repro.checkpoint.layouts).
        """
        from .layout import param_layout_kind
        kind = param_layout_kind(strategy or self.cfg.strategy)
        if kind == "zero1" and not self.topo.node_axes:
            return "replicated"
        return kind

    # -- dispatch core ---------------------------------------------------
    def _default_strategy(self, collective: str) -> str:
        if collective == "prefetch_allgather":
            # -1 is the blocking negative control of the prefetch proof
            return "blocking" if self.cfg.prefetch_blocks == -1 \
                else "lane_pipelined"
        s = self.cfg.strategy
        return s if s == "auto" or has_impl(collective, s) else "auto"

    def _dispatch(self, collective: str, x: Any, strategy: Optional[str],
                  **kw) -> Any:
        strategy = strategy or self._default_strategy(collective)
        if strategy == "auto":
            payload = _payload_bytes(x)
            strategy, ranking = self.select(collective, payload,
                                            lead=_lead(x))
            if self.cfg.record_selections:
                self.selections.append(
                    Selection(collective, strategy, payload, ranking,
                              self._select_source))
        return get_impl(collective, strategy).fn(self, x, **kw)

    # -- the collective surface (paper §3, Listings 1-6 + Scan) ----------
    def allreduce(self, x, *, strategy: Optional[str] = None, **kw):
        """Sum over the whole (node × lane) communicator, on every chip."""
        return self._dispatch("allreduce", x, strategy, **kw)

    def reduce_scatter(self, x, *, strategy: Optional[str] = None, **kw):
        """Reduce p·m rows; each chip keeps its global-rank block of m."""
        return self._dispatch("reduce_scatter", x, strategy, **kw)

    def allgather(self, x, *, strategy: Optional[str] = None, **kw):
        """Concatenate every chip's block in global-rank order."""
        return self._dispatch("allgather", x, strategy, **kw)

    def bcast(self, x, *, strategy: Optional[str] = None, **kw):
        """Broadcast the root chip's buffer (SPMD masked-root convention)."""
        return self._dispatch("bcast", x, strategy, **kw)

    def alltoall(self, x, *, strategy: Optional[str] = None, **kw):
        """Personalized exchange: destination-rank blocks → source-rank."""
        return self._dispatch("alltoall", x, strategy, **kw)

    def moe_route(self, x, *, strategy: Optional[str] = None, **kw):
        """Token-routing alltoall (MoE expert dispatch/combine).

        Same exchange semantics as :meth:`alltoall` — destination-rank
        blocks in, source-rank blocks out — but registered as its own
        collective so the tuner prices it at routing payloads and the
        benchmarks/selections can tell routing traffic from generic
        alltoall use.  The hot caller is :func:`repro.models.moe.
        moe_block_ep`."""
        return self._dispatch("moe_route", x, strategy, **kw)

    def reduce(self, x, *, strategy: Optional[str] = None, **kw):
        """Sum valid on the root chip, zeros elsewhere."""
        return self._dispatch("reduce", x, strategy, **kw)

    def gather(self, x, *, strategy: Optional[str] = None, **kw):
        """All blocks on the root chip in global-rank order, zeros elsewhere."""
        return self._dispatch("gather", x, strategy, **kw)

    def scatter(self, x, *, strategy: Optional[str] = None, **kw):
        """Each chip receives its global-rank block of the root's buffer."""
        return self._dispatch("scatter", x, strategy, **kw)

    def scan(self, x, *, strategy: Optional[str] = None, **kw):
        """Inclusive prefix sum by consecutive global rank (MPI_Scan)."""
        return self._dispatch("scan", x, strategy, **kw)

    # -- composite training collectives ----------------------------------
    def grad_sync(self, grads, *, strategy: Optional[str] = None,
                  num_buckets: Optional[int] = None, **kw):
        """Synchronize (mean) a gradient pytree over the batch axes.

        Returns the fully-reduced tree, or (sharded_flat, spec) for the
        ZeRO strategies — see the registered implementations in
        :mod:`repro.comm.impls` for the per-strategy contracts.
        ``num_buckets``: None = ``cfg.buckets``; 0 = cost-model auto.
        Extra keywords flow to the implementation (``lane_quorum`` takes
        ``contributing=``, this pod's 0/1 watchdog bit).
        """
        nb = self.cfg.buckets if num_buckets is None else num_buckets
        return self._dispatch("grad_sync", grads, strategy,
                              num_buckets=nb, **kw)

    def prefetch_allgather(self, shard, *, strategy: Optional[str] = None,
                           num_blocks: Optional[int] = None):
        """Re-gather a 1/p ZeRO-3 stripe to the full flat vector.

        Default strategy follows ``cfg.prefetch_blocks``: -1 dispatches
        to the monolithic ``"blocking"`` gather (the negative control),
        anything else to the §5 ``"lane_pipelined"`` AG(lane)→AG(node).
        """
        return self._dispatch("prefetch_allgather", shard, strategy,
                              num_blocks=num_blocks)

    # -- composite serving collective ------------------------------------
    def kv_splice(self, big, *, small, slot, batch_axis: int = 1,
                  strategy: Optional[str] = None, **kw):
        """Write a batch-1 cache leaf (valid on the root chip, masked-root
        convention) into global slot ``slot`` of the slot-sharded leaf
        ``big``: a rooted bcast of the leaf + a purely local splice — the
        serving-side KV distribution primitive.  ``"lane"`` broadcasts
        through the §3 decomposed lane bcast; ``"native"`` is the
        one-shot psum baseline.  Never auto-selected (the result layout
        depends on slot ownership, not payload cost).
        """
        return self._dispatch("kv_splice", big, strategy or "lane",
                              small=small, slot=slot,
                              batch_axis=batch_axis, **kw)
