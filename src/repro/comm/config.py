"""CommConfig — the typed communication config behind one LaneComm.

Absorbs the loose per-field knobs that used to ride on ``RunConfig``
(``gradsync`` strategy string, ``gradsync_buckets``, ``fsdp_prefetch``)
behind one frozen dataclass, so a LaneComm carries its whole tuning
surface and a new knob is one field here instead of a new int threaded
through every call site.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.configs.base import RunConfig
    from repro.tuning.table import Tuner

_COMPRESSIONS = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Tuning surface of one :class:`~repro.comm.LaneComm`.

    strategy: default strategy for ``grad_sync`` (and any collective for
        which that name is registered).  ``"auto"`` ranks the registered
        auto-eligible implementations with the cost model per call.
    buckets: gradient-sync bucket count K; 0 = cost-model auto (the §5
        latency/bandwidth crossover, ``core.costmodel.optimal_num_buckets``).
    prefetch_blocks: ZeRO-3 per-layer weight-gather pipeline blocks B;
        0 = cost-model auto, >0 = override, -1 = BLOCKING gather (the
        negative control: ``prefetch_allgather`` dispatches to the
        ``"blocking"`` strategy).
    compression: DCN payload compression ("none" | "int8").  Descriptive
        — ``lane_int8`` is never auto-selected (lossy); this records that
        the owner opted in.
    record_selections: append a Selection record per auto dispatch (read
        by the HLO structural checkers / benchmarks).
    tuner: measured-cost hook (``repro.tuning.table.Tuner``).  When set,
        ``LaneComm.select`` asks it for a MEASURED cost per candidate
        strategy and ranks measured cells ahead of closed-form-modelled
        ones (unmeasured cells fall back to the §3/§5 model — the
        measure-once-then-commit contract, DESIGN.md §11).  None (the
        default) keeps dispatch purely on the closed-form model.
    """

    strategy: str = "auto"
    buckets: int = 0
    prefetch_blocks: int = 0
    compression: str = "none"
    record_selections: bool = True
    tuner: Optional[Tuner] = None

    def __post_init__(self):
        if self.compression not in _COMPRESSIONS:
            raise ValueError(
                f"unknown compression {self.compression!r}; "
                f"have {_COMPRESSIONS}")
        if self.strategy != "auto":
            # catch typos at construction: a default strategy must name
            # SOME registration (per-collective resolution still falls
            # back to auto where the name isn't registered — deliberate)
            from .registry import has_impl, registered_collectives
            if not any(has_impl(c, self.strategy)
                       for c in registered_collectives()):
                raise ValueError(
                    f"unknown strategy {self.strategy!r}: not registered "
                    f"for any collective (inspect the tables via "
                    f"repro.comm.strategies_for)")

    @classmethod
    def from_run(cls, run: "RunConfig") -> "CommConfig":
        """Bridge from the legacy RunConfig knobs (kept for back-compat)."""
        return cls(
            strategy=run.gradsync,
            buckets=run.gradsync_buckets,
            prefetch_blocks=run.fsdp_prefetch,
            compression="int8" if run.gradsync == "lane_int8" else "none",
        )
