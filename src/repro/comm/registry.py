"""The collective-implementation registry behind :class:`~repro.comm.LaneComm`.

The paper's decomposition gives every collective a *family* of correct
implementations (native one-shot, full-lane mock-up, §5 pipelined, …).
Before this module those variants fanned out through hand-written ``if``
chains at every call site (``optim/gradsync.py``, ``launch/steps.py``),
so each new variant was a three-site edit.  Here each implementation is a
one-decorator registration::

    @register_impl("allreduce", "lane_pipelined",
                   cost=cost_pipelined_allreduce)
    def _impl(comm, x, **kw): ...

and the dispatcher resolves ``(collective, strategy)`` through the table.
The optional ``cost`` callable — ``cost(n, N, payload_bytes, cfg) ->
seconds`` — is what makes the paper's self-consistent performance
guidelines *executable*: ``strategy="auto"`` ranks every auto-eligible
registration with the §3/§5 cost model and picks the cheapest (see
DESIGN.md §6 for the ranking rule).

Error messages and documentation derive the valid-strategy lists from
this table (never from a hard-coded tuple), so a new registration is
self-documenting.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = [
    "ImplEntry", "register_impl", "get_impl", "has_impl",
    "strategies_for", "registered_collectives", "iter_impls",
]


@dataclasses.dataclass(frozen=True)
class ImplEntry:
    """One registered implementation of one collective.

    cost: ``(n, N, payload_bytes, cfg) -> seconds`` under the §3/§5 cost
        model (n = processes per node, N = nodes).  Entries without a
        cost are never auto-selected.
    auto_ok: eligible for ``strategy="auto"``.  False for lossy
        (``lane_int8``) or layout-changing (``lane_zero1``/``lane_zero3``)
        implementations whose results are not interchangeable with the
        exact full-payload ones.
    feasible: ``(n, N, lead) -> bool`` — divisibility precondition on the
        leading payload dimension; auto skips infeasible entries instead
        of tracing into their ValueError.
    probe_ok: eligibility for the measured-cost probe sweep,
        INDEPENDENT of auto-eligibility.  None (default) falls back to
        the auto rule (auto_ok and priced); True forces probing of cells
        that can never win auto dispatch but whose measured time is
        still wanted (the blocking prefetch negative control); False
        excludes a priced cell from the sweep.
    """
    collective: str
    strategy: str
    fn: Callable
    cost: Optional[Callable] = None
    auto_ok: bool = True
    feasible: Optional[Callable] = None
    probe_ok: Optional[bool] = None

    @property
    def probe_eligible(self) -> bool:
        """Should the timing probe measure this cell?"""
        if self.probe_ok is not None:
            return self.probe_ok
        return self.auto_ok and self.cost is not None


_REGISTRY: dict[str, dict[str, ImplEntry]] = {}


def register_impl(collective: str, strategy: str, *,
                  cost: Optional[Callable] = None, auto_ok: bool = True,
                  feasible: Optional[Callable] = None,
                  probe_ok: Optional[bool] = None,
                  override: bool = False) -> Callable:
    """Decorator: register ``fn(comm, payload, **kw)`` for a collective.

    Re-registering the same (collective, strategy) raises unless
    ``override=True`` — silent shadowing is how dispatch tables rot.
    """
    def deco(fn):
        table = _REGISTRY.setdefault(collective, {})
        if strategy in table and not override:
            raise ValueError(
                f"{collective!r} strategy {strategy!r} already registered "
                f"(by {table[strategy].fn.__module__}); pass override=True "
                f"to replace it")
        table[strategy] = ImplEntry(collective, strategy, fn, cost=cost,
                                    auto_ok=auto_ok, feasible=feasible,
                                    probe_ok=probe_ok)
        return fn
    return deco


def get_impl(collective: str, strategy: str) -> ImplEntry:
    """Resolve one registration; unknown names list what IS registered."""
    table = _REGISTRY.get(collective)
    if not table:
        raise ValueError(
            f"no implementations registered for collective {collective!r}; "
            f"registered collectives: {registered_collectives()}")
    if strategy not in table:
        raise ValueError(
            f"unknown strategy {strategy!r} for collective {collective!r}; "
            f"registered strategies: {strategies_for(collective)}")
    return table[strategy]


def has_impl(collective: str, strategy: str) -> bool:
    return strategy in _REGISTRY.get(collective, {})


def strategies_for(collective: str) -> tuple[str, ...]:
    """Registered strategy names for one collective, registration order."""
    return tuple(_REGISTRY.get(collective, {}))


def registered_collectives() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def iter_impls(collective: str) -> tuple[ImplEntry, ...]:
    return tuple(_REGISTRY.get(collective, {}).values())
