"""Parameter-layout descriptors for the registered train-step strategies.

A gradsync strategy is more than a collective schedule: the ZeRO flavors
change where the MASTER parameters and optimizer moments live (fully
replicated tree vs node-sharded flat vector vs the bucket-major 1/p
(L, B, p, s) layer stack of DESIGN.md §5).  Everything outside the jitted
step — the training driver's state init, the shard_map in/out specs, and
above all the checkpoint store — must agree with the step on that layout,
and before this module each consumer hard-coded its own copy of the
mapping.

Here the mapping is one more registry: every ``train_step`` registration
declares its layout kind via :func:`register_param_layout`, and
:meth:`LaneComm.param_layout <repro.comm.LaneComm.param_layout>` answers
the question "what master layout does this strategy expect on THIS
topology" — including the single-batch-axis degradation (an empty node
level collapses ZeRO-1 to the replicated native step, mirroring the step
builders in :mod:`repro.launch.steps`).

Kinds:

  replicated  params and optimizer state are ordinary pytrees, identical
              on every chip (native / lane / lane_pipelined / lane_int8 /
              auto).
  zero1       params replicated; optimizer moments are ONE flat fp32
              vector sharded over the node axes in the bucket-major
              ``gradsync.zero1_param_shard`` layout.
  zero3       the family's scanned layer stack AND the embeddings/
              final-norm "extras" pseudo-layer (params AND moments) live
              in the bucket-major (L, B, p, s) master layouts of
              ``repro.models.blockstack.shard_stack``; only the family
              spec's replicated_keys (the hybrid weight-shared attention
              block) stay replicated.

The concrete checkpoint canonicalization for each kind lives in
:mod:`repro.checkpoint.layouts`.
"""
from __future__ import annotations

PARAM_LAYOUT_KINDS = ("replicated", "zero1", "zero3")

_TABLE: dict[str, str] = {}


def register_param_layout(strategy: str, kind: str) -> None:
    """Declare the master-parameter layout of one train-step strategy.

    Called next to the strategy's ``@register_impl("train_step", ...)``
    registration; re-registering with a DIFFERENT kind raises (the layout
    is a contract every checkpoint ever written under the strategy
    depends on).
    """
    if kind not in PARAM_LAYOUT_KINDS:
        raise ValueError(
            f"unknown param layout kind {kind!r}; have {PARAM_LAYOUT_KINDS}")
    old = _TABLE.get(strategy)
    if old is not None and old != kind:
        raise ValueError(
            f"train-step strategy {strategy!r} already registered with "
            f"param layout {old!r}; cannot re-register as {kind!r}")
    _TABLE[strategy] = kind


def param_layout_kind(strategy: str) -> str:
    """The registered layout kind for ``strategy`` (topology-blind —
    use :meth:`LaneComm.param_layout` for the degradation-aware answer)."""
    if strategy not in _TABLE:
        raise ValueError(
            f"no param layout registered for train-step strategy "
            f"{strategy!r}; registered: {tuple(_TABLE)}")
    return _TABLE[strategy]
