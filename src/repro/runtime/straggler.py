"""Straggler mitigation: bounded-staleness quorum on the cross-pod hop.

At multi-pod scale the DCN hop is the straggler magnet (one slow host
delays the whole allreduce).  The paper's decomposition isolates exactly
that hop — Allreduce(lane) on 1/n payloads — which makes it the natural
place for a quorum: pods that miss the deadline contribute zero and the
mean is rescaled by the number of contributors.

Without real hardware timeouts, the quorum is expressed as a mask input
(tests drive it directly); on a real fleet the mask comes from the
host-side watchdog that observes per-pod progress counters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quorum_stage(lane_axis: str, contributing):
    """Bucket-schedule stage: quorum allreduce-mean over the lane axis.

    The lane_quorum grad-sync replaces ``_ar_lane`` (plain psum) with
    this stage inside the same RS(node) → AR(lane) → AG(node) schedule:
    each bucket is masked by THIS pod's contributing bit and divided by
    the live count.  The divisor is hoisted out of the per-bucket
    closure — one scalar psum for the whole schedule, not one per
    bucket.  With an all-ones mask the stage computes psum(x·1)/P,
    which on power-of-two pod counts is bit-identical to the ``lane``
    strategy's psum followed by its deferred /P.
    """
    c = jnp.asarray(contributing, jnp.float32)
    den = jnp.maximum(lax.psum(c, lane_axis), 1.0)

    def stage(v):
        cv = c.astype(v.dtype)
        return lax.psum(v * cv, lane_axis) / den.astype(v.dtype)
    return stage


def quorum_mean(x, lane_axis: str, contributing):
    """Mean of `x` over the lane (pod) axis counting only contributors.

    x: per-pod value (inside shard_map); contributing: scalar bool/0-1 for
    THIS pod.  Non-contributors are zeroed; the divisor is the live count
    (min 1).  Deterministic given the mask — a dropped pod changes the
    gradient exactly as if its microbatch were skipped, which the
    (seed, step)-keyed data pipeline can replay later.
    """
    c = contributing.astype(x.dtype) if hasattr(contributing, "astype") \
        else jnp.asarray(contributing, x.dtype)
    num = lax.psum(x * c, lane_axis)
    den = lax.psum(jnp.asarray(c, jnp.float32), lane_axis)
    return num / jnp.maximum(den, 1.0).astype(x.dtype)
