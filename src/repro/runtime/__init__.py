from .elastic import ElasticMesh, plan_elastic_mesh
from .faults import Fault, FaultPlan, corrupt_leaf_file, parse_fault_plan
from .health import DEGRADED, HEALTHY, RESTART, HealthEvent, HealthMonitor
from .straggler import quorum_mean, quorum_stage
from .watchdog import Watchdog
