from .elastic import ElasticMesh, plan_elastic_mesh
from .straggler import quorum_mean
