"""Run-health state machine: HEALTHY → DEGRADED → RESTART.

The driver's recovery ladder (launch/train.py) has exactly three rungs:

  HEALTHY    every pod heartbeating — full-quorum steps, bit-identical
             to a run with no fault machinery at all
  DEGRADED   some pod(s) masked out of the quorum — steps proceed with
             ``quorum_mean``-rescaled gradients, the dropped
             (seed, step)-keyed microbatches are logged for replay, and
             a bounded-staleness clock ticks per stale pod
  RESTART    a pod exceeded the staleness bound (or the strategy cannot
             degrade) — emergency-save and re-plan the mesh without it

The monitor is deliberately dumb-deterministic: state is a pure function
of the observed mask history, so a resumed driver replaying the same
fault plan reaches the same transitions at the same steps.  Every
transition is logged (and kept in ``events``) — silence is how recovery
ladders rot.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

HEALTHY = "HEALTHY"
DEGRADED = "DEGRADED"
RESTART = "RESTART"


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One state transition: at forming ``step``, ``old`` → ``new``
    because of ``reason`` (human-readable)."""
    step: int
    old: str
    new: str
    reason: str


class HealthMonitor:
    """Fold per-step contributing masks into the ladder state.

    staleness_limit: K — consecutive masked steps a pod may accumulate
        while the run is DEGRADED before escalating to RESTART.  The
        bound is per pod and resets the moment the pod heartbeats again
        (a slow pod that recovers never triggers a restart).
    can_degrade: False when the active grad-sync strategy has no quorum
        path (every non-``lane_quorum`` strategy) — any masked pod then
        escalates straight to RESTART, because a step simply cannot be
        formed without it.
    log: print-like sink for transition lines (None = silent).
    """

    def __init__(self, num_pods: int, staleness_limit: int = 2,
                 can_degrade: bool = True,
                 log: Optional[Callable[[str], None]] = print):
        self.num_pods = num_pods
        self.staleness_limit = max(int(staleness_limit), 1)
        self.can_degrade = can_degrade
        self._log = log
        self.state = HEALTHY
        self.events: list[HealthEvent] = []
        self._stale_streak = np.zeros((num_pods,), np.int64)

    # -- core -------------------------------------------------------------
    def observe(self, step: int, mask) -> str:
        """Fold the mask for forming step ``step``; returns the new state.

        RESTART is terminal for this attempt: the driver is expected to
        emergency-save, re-plan around :meth:`restart_pods`, and build a
        fresh monitor for the shrunken mesh.
        """
        if self.state == RESTART:
            return self.state
        m = np.asarray(mask)
        if m.shape != (self.num_pods,):
            raise ValueError(
                f"mask shape {m.shape} != ({self.num_pods},)")
        stale = m == 0
        self._stale_streak = np.where(stale, self._stale_streak + 1, 0)
        if not stale.any():
            self._to(HEALTHY, step, "all pods heartbeating")
            return self.state
        who = [int(i) for i in np.nonzero(stale)[0]]
        if not self.can_degrade:
            self._to(RESTART, step,
                     f"pods {who} stale and strategy cannot degrade "
                     f"(no quorum grad-sync)")
        elif int(self._stale_streak.max()) > self.staleness_limit:
            worst = [int(i) for i in
                     np.nonzero(self._stale_streak
                                > self.staleness_limit)[0]]
            self._to(RESTART, step,
                     f"pods {worst} exceeded staleness bound "
                     f"K={self.staleness_limit}")
        else:
            self._to(DEGRADED, step,
                     f"pods {who} masked (streak "
                     f"{int(self._stale_streak.max())}/"
                     f"{self.staleness_limit})")
        return self.state

    def restart_pods(self) -> tuple:
        """Lane ranks whose staleness triggered (or outlived) the RESTART
        — the pods the elastic replan must exclude."""
        return tuple(int(i) for i in
                     np.nonzero(self._stale_streak > 0)[0])

    # -- internals --------------------------------------------------------
    def _to(self, new: str, step: int, reason: str) -> None:
        if new == self.state:
            return
        ev = HealthEvent(step, self.state, new, reason)
        self.events.append(ev)
        self.state = new
        if self._log is not None:
            self._log(f"health: step {step}: {ev.old} -> {ev.new} "
                      f"({reason})")
