"""Deterministic fault injection for the lane runtime.

On a real fleet faults arrive from the outside: a pod's host stalls, a
pod drops off the DCN, a checkpoint write hits a flaky filesystem, a
committed file rots on disk.  None of that is reproducible under tier-1,
so the driver takes a :class:`FaultPlan` instead — a seeded, declarative
schedule of the same four fault classes — and every recovery path
(quorum-masked DEGRADED steps, the emergency-save RESTART ladder, the
checkpoint retry/fallback machinery) runs deterministically on a laptop
CPU mesh with no real hardware.

Fault kinds (``Fault.kind``):
  pod_slow      pod misses its progress heartbeat for steps [step, until]
                (inclusive) — the watchdog masks it out of the quorum
  pod_lost      pod stops heartbeating at ``step`` and never returns —
                the health ladder escalates DEGRADED → RESTART
  ckpt_io       the checkpoint save whose step == ``step`` raises OSError
                on its first ``count`` write attempts (transient I/O;
                exercised against save_checkpoint's bounded retry)
  corrupt_leaf  AFTER the step-``step`` checkpoint commits, flip one byte
                of ``arr_<leaf>.npy`` — the crc32 manifest check must
                refuse it and restore falls back to the previous step

Spec grammar (one fault per ``;``-separated clause)::

    pod_slow@2-4:pod=1; pod_lost@5:pod=0; ckpt_io@6:count=2;
    corrupt_leaf@8:leaf=3

``kind@step[-until][:key=int,...]``.  Pod ids are CURRENT-mesh lane
ranks; after an elastic shrink the surviving pods renumber, so entries
whose pod id falls off the new (smaller) lane axis are simply inert —
exactly like a lost machine that is no longer part of the job.

numpy-only on purpose: the plan is consulted on the host between steps
and inside checkpoint worker threads — it must import (and run) without
touching jax.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Callable, Optional, Sequence

import numpy as np

KINDS = ("pod_slow", "pod_lost", "ckpt_io", "corrupt_leaf")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault (see the kind table in the module docstring).

    until: last affected step for pod_slow (inclusive; defaults to
        ``step``); ignored by the other kinds (pod_lost is forever).
    pod: lane rank the pod_* kinds target.
    count: how many save attempts fail for ckpt_io (1 = first only).
    leaf: arr_<leaf>.npy index corrupt_leaf flips a byte of.
    """
    kind: str
    step: int
    until: int = -1
    pod: int = 0
    count: int = 1
    leaf: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {KINDS}")
        if self.until < 0:
            object.__setattr__(self, "until", self.step)
        if self.until < self.step:
            raise ValueError(f"fault window [{self.step}, {self.until}] "
                             f"is empty")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable schedule of :class:`Fault` entries.

    Query methods are pure functions of (plan, step) — the driver asks
    the same questions every step and a resumed driver asking about past
    steps gets the same answers (restart determinism).
    """
    faults: tuple = ()

    # -- construction -----------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the CLI grammar (module docstring); '' → empty plan."""
        faults = []
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            head, _, tail = clause.partition(":")
            kind, _, window = head.partition("@")
            kind = kind.strip()
            if not window:
                raise ValueError(
                    f"fault clause {clause!r} missing '@step'")
            a, _, b = window.partition("-")
            kw = {"kind": kind, "step": int(a),
                  "until": int(b) if b else -1}
            for item in filter(None,
                               (s.strip() for s in tail.split(","))):
                k, _, v = item.partition("=")
                if k not in ("pod", "count", "leaf"):
                    raise ValueError(
                        f"unknown fault option {k!r} in {clause!r}")
                kw[k] = int(v)
            faults.append(Fault(**kw))
        return cls(tuple(faults))

    @classmethod
    def generate(cls, seed: int, steps: int, num_pods: int,
                 rate: float = 0.25) -> "FaultPlan":
        """Seeded random plan: a reproducible chaos-test schedule.

        Draws up to one fault per class over the run, placed uniformly
        in [1, steps); ``rate`` is the per-class inclusion probability.
        Deterministic in (seed, steps, num_pods, rate).
        """
        rng = np.random.default_rng(seed)
        faults = []
        if steps < 2:
            return cls(())
        for kind in KINDS:
            if rng.random() >= rate:
                continue
            s = int(rng.integers(1, steps))
            if kind == "pod_slow":
                faults.append(Fault(kind, s,
                                    until=min(steps - 1, s + int(
                                        rng.integers(1, 3))),
                                    pod=int(rng.integers(0, num_pods))))
            elif kind == "pod_lost":
                faults.append(Fault(kind, s,
                                    pod=int(rng.integers(0, num_pods))))
            elif kind == "ckpt_io":
                faults.append(Fault(kind, s,
                                    count=int(rng.integers(1, 3))))
            else:
                faults.append(Fault(kind, s, leaf=int(rng.integers(0, 4))))
        return cls(tuple(faults))

    # -- queries ----------------------------------------------------------
    def pods_down(self, step: int, num_pods: int) -> tuple:
        """Lane ranks NOT heartbeating at ``step`` (sorted, deduped).

        pod_slow covers its [step, until] window; pod_lost covers every
        step >= its start.  Entries targeting pods outside the current
        lane axis (``pod >= num_pods`` after an elastic shrink) are
        inert.
        """
        down = set()
        for f in self.faults:
            if f.pod >= num_pods:
                continue
            if f.kind == "pod_slow" and f.step <= step <= f.until:
                down.add(f.pod)
            elif f.kind == "pod_lost" and step >= f.step:
                down.add(f.pod)
        return tuple(sorted(down))

    def lost_pods(self, step: int, num_pods: int) -> tuple:
        """The PERMANENTLY lost subset of :meth:`pods_down` — what the
        RESTART replan must exclude (slow pods come back; lost ones
        don't)."""
        return tuple(sorted(
            f.pod for f in self.faults
            if f.kind == "pod_lost" and step >= f.step
            and f.pod < num_pods))

    def ckpt_failures(self, step: int) -> int:
        """How many save attempts of the step-``step`` checkpoint fail."""
        return sum(f.count for f in self.faults
                   if f.kind == "ckpt_io" and f.step == step)

    def ckpt_attempt_hook(self, step: int) -> Optional[Callable[[int], None]]:
        """An ``attempt_hook(attempt)`` for ``save_checkpoint``: raises
        OSError on the first ``ckpt_failures(step)`` attempts (0-based),
        then lets the write through.  None when no ckpt_io fault covers
        this step — the hot path stays hook-free."""
        fail = self.ckpt_failures(step)
        if not fail:
            return None

        def hook(attempt: int) -> None:
            if attempt < fail:
                raise OSError(
                    f"injected transient checkpoint I/O error "
                    f"(step {step}, attempt {attempt + 1}/{fail} failing)")
        return hook

    def corrupt_at(self, step: int) -> Optional[int]:
        """arr index to corrupt after the step-``step`` commit, or None."""
        for f in self.faults:
            if f.kind == "corrupt_leaf" and f.step == step:
                return f.leaf
        return None

    def __bool__(self):
        return bool(self.faults)


def corrupt_leaf_file(ckpt_dir: str, step: int, leaf: int) -> pathlib.Path:
    """Flip the last byte of ``step_<step>/arr_<leaf>.npy`` in place.

    The .npy header stays intact, so np.load still succeeds — only the
    manifest crc32 can tell.  (Flipping the LAST byte also corrupts the
    actual array data, not padding: np.save writes the raw buffer last.)
    Returns the corrupted path; raises FileNotFoundError when the leaf
    does not exist (a plan targeting a leaf index past the tree is a
    test bug worth failing loudly on).
    """
    p = pathlib.Path(ckpt_dir) / f"step_{step}" / f"arr_{leaf}.npy"
    raw = bytearray(p.read_bytes())
    if not raw:
        raise ValueError(f"{p} is empty")
    raw[-1] ^= 0xFF
    p.write_bytes(bytes(raw))
    return p


def parse_fault_plan(spec: str) -> FaultPlan:
    """CLI convenience alias: '' → empty plan, else the spec grammar."""
    return FaultPlan.parse(spec)
