"""Host-side per-pod progress watchdog → the quorum contributing mask.

The paper's decomposition isolates the cross-pod hop (Allreduce(lane) on
1/n payloads), which makes the pod the natural quorum unit: one stalled
pod delays exactly one lane-axis participant, and ``quorum_mean``
(runtime/straggler.py) was designed to take a 0/1 contributing mask and
rescale the mean by the live count.  This module produces that mask.

On a real fleet each pod's host bumps a progress counter (steps
completed) in a shared store (borg task state / jax.distributed kv);
the driver's watchdog reads them and declares any pod whose counter
lags the current step by more than ``deadline_steps`` non-contributing.
Under tier-1 there is one process, so the driver feeds heartbeats
itself — from a :class:`~repro.runtime.faults.FaultPlan` — and the
deadline arithmetic is identical.

numpy-only: consulted between steps on the host, never traced.
"""
from __future__ import annotations

import numpy as np


class Watchdog:
    """Deadline-based liveness over per-pod progress heartbeats.

    deadline_steps: how many steps a pod's last heartbeat may lag the
        step being formed before the pod is masked out.  0 = strict
        (must have heartbeat at the current step).
    """

    def __init__(self, num_pods: int, deadline_steps: int = 0):
        if num_pods < 1:
            raise ValueError(f"num_pods must be >= 1, got {num_pods}")
        self.num_pods = num_pods
        self.deadline_steps = deadline_steps
        # -1 = never heard from; a pod that heartbeats step 0 is live
        self._last = np.full((num_pods,), -1, np.int64)

    def heartbeat(self, pod: int, step: int) -> None:
        """Record pod ``pod`` having COMPLETED (or reached) ``step``.

        Heartbeats are monotone: a late-arriving older heartbeat never
        rolls a pod's progress back.
        """
        if not 0 <= pod < self.num_pods:
            raise ValueError(f"pod {pod} outside [0, {self.num_pods})")
        self._last[pod] = max(self._last[pod], int(step))

    def mask(self, step: int) -> np.ndarray:
        """0/1 contributing mask (float32, shape (num_pods,)) for forming
        step ``step``: pod i contributes iff its last heartbeat is within
        ``deadline_steps`` of ``step``."""
        return (step - self._last <= self.deadline_steps) \
            .astype(np.float32)

    def live(self, step: int) -> tuple:
        """Sorted lane ranks contributing at ``step``."""
        return tuple(int(i) for i in np.nonzero(self.mask(step))[0])

    def stale(self, step: int) -> tuple:
        """Sorted lane ranks masked OUT at ``step``."""
        return tuple(int(i) for i in np.nonzero(self.mask(step) == 0)[0])
