"""Elastic mesh management: survive node/pod loss without a recompile.

Policy (1000+-node design):
  * The "model" axis is sacred — losing a chip of a TP group kills that
    whole group's pod-slice, so re-planning only ever shrinks the batch
    axes ("pod", then "data").
  * Shrinking a batch axis keeps every per-chip array shape identical
    (batch is divided by the axis), so the step function does NOT need to
    recompile — only the data loader's num_hosts and the grad-sync divisor
    change.
  * Parameters re-enter via the cross-mesh checkpoint restore (store.py);
    in-memory survivors could also re-shard via device_put, which this
    planner expresses as the (old_sharding → new_sharding) mapping.

On real fleets, failure detection is the runtime's heartbeat (borg/GKE +
jax.distributed); here `plan_elastic_mesh` is pure topology math and is
unit-tested by masking devices.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax


@dataclasses.dataclass(frozen=True)
class ElasticMesh:
    axis_names: tuple[str, ...]
    shape: tuple[int, ...]
    lost: tuple[int, ...]          # flat indices of lost devices
    global_batch_scale: float      # new_batch / old_batch (same per-chip)

    def make(self, devices=None):
        import numpy as np
        devices = list(devices if devices is not None else jax.devices())
        n = math.prod(self.shape)
        if len(devices) < n:
            raise ValueError(f"need {n} devices, have {len(devices)}")
        return jax.sharding.Mesh(
            np.asarray(devices[:n], dtype=object).reshape(self.shape),
            self.axis_names)


def plan_elastic_mesh(axis_names: Sequence[str], shape: Sequence[int],
                      lost_flat_indices: Sequence[int]) -> ElasticMesh:
    """Given lost device indices, shrink batch axes to exclude them.

    Returns the largest surviving mesh with the same axis names and the
    same non-batch axis sizes.  Raises if the model axis itself cannot be
    preserved (no full TP slice survives).
    """
    axis_names = tuple(axis_names)
    shape = list(shape)
    lost = set(int(i) for i in lost_flat_indices)
    n = math.prod(shape)
    if not lost:
        return ElasticMesh(axis_names, tuple(shape), (), 1.0)

    # flat index → coordinates (row-major over axes)
    def coords(i):
        out = []
        for s in reversed(shape):
            out.append(i % s)
            i //= s
        return tuple(reversed(out))

    batch_axes = [a for a in ("pod", "data") if a in axis_names]
    if not batch_axes:
        raise ValueError("no batch axis to shrink")
    # find smallest prefix of the outermost batch axis to drop such that
    # all lost devices fall in dropped slices
    outer = axis_names.index(batch_axes[0])
    bad = sorted({coords(i)[outer] for i in lost})
    new_size = shape[outer] - len(bad)
    if new_size < 1:
        raise ValueError("all slices of the outer batch axis lost")
    scale = new_size / shape[outer]
    new_shape = list(shape)
    new_shape[outer] = new_size
    return ElasticMesh(axis_names, tuple(new_shape), tuple(sorted(lost)),
                       scale)
